"""Fused coded-matmul kernel + single-dispatch round pipeline.

Parity of ``coded_matmul`` (interpret mode) against the unfused
encode → per-worker matmul → decode oracle over N/K/T, dtype and
straggler-mask sweeps; the no-full-payload-padding regression for the
upgraded kernels; and the recompile-count contract of the jitted round
path (shape change recompiles, mask change never does)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import registry
from repro.kernels import ref
from repro.kernels.berrut_encode import berrut_encode_kernel
from repro.kernels.coded_matmul import coded_matmul_kernel
from repro.kernels.ops import coded_matmul
from repro.runtime.master_worker import DistributedMatmul

rng = np.random.default_rng(0)


# --------------------------------------------------------------------------
# kernel parity: (W @ blocks) @ B fused vs the unfused oracle
# --------------------------------------------------------------------------

@pytest.mark.parametrize("n,j,blk,d,nout", [
    (30, 27, 22, 512, 256),     # fig-3 scale: N=30, J=K+T=24+3
    (10, 4, 64, 64, 32),
    (12, 5, 16, 48, 10),        # K=3, T=2
    (3, 3, 7, 130, 17),         # ragged everything
    (8, 8, 128, 256, 128),      # fully aligned
    (33, 33, 5, 1000, 3),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_coded_matmul_kernel_matches_unfused_oracle(n, j, blk, d, nout, dtype):
    w = jnp.asarray(rng.standard_normal((n, j)), jnp.float32)
    blocks = jnp.asarray(rng.standard_normal((j, blk, d)), dtype)
    rhs = jnp.asarray(rng.standard_normal((d, nout)), dtype)
    out = coded_matmul_kernel(w, blocks, rhs, interpret=True)
    want = ref.coded_matmul(w, blocks, rhs)
    assert out.shape == want.shape and out.dtype == want.dtype
    rel = (float(jnp.max(jnp.abs(out.astype(jnp.float32) -
                                 want.astype(jnp.float32)))) /
           max(float(jnp.max(jnp.abs(want.astype(jnp.float32)))), 1e-9))
    tol = 1e-4 if dtype == jnp.float32 else 0.1
    assert rel < tol, (n, j, blk, d, nout, dtype, rel)


def test_coded_matmul_dispatcher_paths_agree():
    w = jnp.asarray(rng.standard_normal((9, 5)), jnp.float32)
    blocks = jnp.asarray(rng.standard_normal((5, 13, 70)), jnp.float32)
    rhs = jnp.asarray(rng.standard_normal((70, 21)), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(coded_matmul(w, blocks, rhs, force_kernel=True)),
        np.asarray(coded_matmul(w, blocks, rhs, force_kernel=False)),
        atol=2e-4, rtol=2e-4)


# --------------------------------------------------------------------------
# fused_round vs the unfused chain, over schemes and straggler masks
# --------------------------------------------------------------------------

FUSED_SCHEMES = {
    "spacdc": dict(n_workers=12, k_blocks=4, t_colluding=2),
    "bacc": dict(n_workers=12, k_blocks=4),
    "mds": dict(n_workers=12, k_blocks=4),
    "lcc": dict(n_workers=12, k_blocks=4, deg_f=1),
    "conv": dict(n_workers=6),
}
# fused-vs-unfused agreement.  The threshold schemes' unfused decode
# inverts the first-`threshold` responder submatrix while the fused masked
# decode least-squares over ALL survivors — both exact, but the f32 pinv of
# the (N, K) generator leaves ~1e-3 of conditioning noise between them.
FUSED_TOL = {"lcc": 2e-3, "mds": 2e-3}
M, D, NOUT = 36, 40, 24
A_NP = rng.standard_normal((M, D)).astype(np.float32)
B_NP = rng.standard_normal((D, NOUT)).astype(np.float32)


def _responder_sets(scheme, mask_seed):
    """Full set + two random straggler subsets of wait-policy size or more."""
    n = scheme.n_workers
    yield np.arange(n)
    if scheme.name == "conv":
        return                            # conv must wait for everyone
    r = np.random.default_rng(mask_seed)
    lo = scheme.wait_policy(0) if not scheme.rateless else max(n - 4, 1)
    for size in (lo, min(lo + 2, n)):
        yield np.sort(r.choice(n, size=size, replace=False))


@pytest.mark.parametrize("name", sorted(FUSED_SCHEMES))
def test_fused_round_matches_unfused_chain(name):
    scheme = registry.build(name, **FUSED_SCHEMES[name])
    assert scheme.supports_fused
    a = jnp.asarray(A_NP)
    b = jnp.asarray(B_NP)
    shards = scheme.encode(a)
    results = jax.vmap(lambda s: s @ b)(shards)
    for resp in _responder_sets(scheme, mask_seed=7):
        unfused = scheme.decode(results[resp], list(resp))
        unfused = np.asarray(scheme.reconstruct_matmul(unfused, M, NOUT))
        mask = np.zeros(scheme.n_workers, np.float32)
        mask[resp] = 1.0
        fused = scheme.fused_round(a, b, jnp.asarray(mask))
        fused = np.asarray(scheme.reconstruct_matmul(fused, M, NOUT))
        rel = np.abs(fused - unfused).max() / max(np.abs(unfused).max(), 1e-9)
        assert rel < FUSED_TOL.get(name, 1e-4), (name, resp, rel)


def test_fused_round_jittable_with_runtime_mask():
    scheme = registry.build("spacdc", n_workers=10, k_blocks=4, t_colluding=1)
    f = jax.jit(lambda a, b, m: scheme.fused_round(a, b, m))
    full = f(jnp.asarray(A_NP), jnp.asarray(B_NP), jnp.ones(10, jnp.float32))
    mask = np.ones(10, np.float32)
    mask[[2, 5]] = 0.0
    part = f(jnp.asarray(A_NP), jnp.asarray(B_NP), jnp.asarray(mask))
    assert full.shape == part.shape == (4, M // 4, NOUT)
    assert np.all(np.isfinite(np.asarray(part)))


def test_fused_round_bf16():
    scheme = registry.build("spacdc", n_workers=10, k_blocks=4)
    out = scheme.fused_round(jnp.asarray(A_NP, jnp.bfloat16),
                             jnp.asarray(B_NP, jnp.bfloat16),
                             jnp.ones(10, jnp.float32))
    assert np.all(np.isfinite(np.asarray(out, np.float32)))


def test_pair_coded_schemes_have_no_fused_path():
    for name, kw in [("polynomial", dict(n_workers=8, p=2, q=2)),
                     ("matdot", dict(n_workers=8, p=2))]:
        scheme = registry.build(name, **kw)
        assert not scheme.supports_fused
        with pytest.raises(NotImplementedError):
            scheme.fused_round(jnp.asarray(A_NP), jnp.asarray(B_NP),
                               jnp.ones(8, jnp.float32))


# --------------------------------------------------------------------------
# padding regression: aligned payloads move zero bytes
# --------------------------------------------------------------------------

def _payload_pad_eqns(jaxpr, payload_size):
    """pad/dynamic_update_slice equations producing >= payload-sized arrays
    (i.e. full-payload copies; the tiny coding-matrix pad is exempt)."""
    bad = []
    for eqn in jaxpr.jaxpr.eqns:
        if eqn.primitive.name in ("pad", "dynamic_update_slice"):
            if any(int(np.prod(v.aval.shape)) >= payload_size
                   for v in eqn.outvars):
                bad.append(eqn)
    return bad


def test_berrut_kernel_no_payload_copy_when_aligned():
    w = jnp.zeros((8, 8), jnp.float32)
    b = jnp.zeros((8, 1024), jnp.float32)
    jx = jax.make_jaxpr(
        lambda w, b: berrut_encode_kernel(w, b, interpret=True))(w, b)
    assert not _payload_pad_eqns(jx, b.size), jx


def test_coded_matmul_kernel_no_payload_copy_when_aligned():
    w = jnp.zeros((8, 8), jnp.float32)
    blocks = jnp.zeros((8, 128, 256), jnp.float32)
    rhs = jnp.zeros((256, 128), jnp.float32)
    jx = jax.make_jaxpr(
        lambda w, a, r: coded_matmul_kernel(w, a, r, interpret=True))(
            w, blocks, rhs)
    assert not _payload_pad_eqns(jx, blocks.size), jx


def test_berrut_kernel_misaligned_still_correct():
    w = jnp.asarray(rng.standard_normal((5, 6)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((6, 999)), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(berrut_encode_kernel(w, b, interpret=True)),
        np.asarray(ref.berrut_combine(w, b)), atol=1e-4, rtol=1e-4)


def test_berrut_kernel_j_past_tile_cap_pads_to_alignment_only():
    """J just past the tile cap must not round the payload up to ~2x: the
    tile shrinks to a divisor of the 8-aligned J instead (gradient-coding
    scale).  bj=8 cap forces the multi-J-tile accumulator path too."""
    from repro.kernels.berrut_encode import _tile
    assert _tile(513, 8, 512) == (104, 520)      # not (512, 1024)
    w = jnp.asarray(rng.standard_normal((4, 33)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((33, 256)), jnp.float32)
    out = berrut_encode_kernel(w, b, bj=8, interpret=True)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.berrut_combine(w, b)),
                               atol=1e-4, rtol=1e-4)


# --------------------------------------------------------------------------
# the jitted round pipeline: recompile only on shape change
# --------------------------------------------------------------------------

def test_fused_round_path_recompiles_only_on_shape_change():
    dist = DistributedMatmul("spacdc", n_workers=8, k_blocks=4,
                             t_colluding=1, n_stragglers=2)
    assert dist.use_fused
    a = A_NP[:32]
    out1, stats1 = dist.matmul(a, B_NP, round_idx=0)
    assert dist.trace_count == 1
    # new round, new straggler mask, same shapes -> NO retrace
    out2, stats2 = dist.matmul(a, B_NP, round_idx=1)
    assert dist.trace_count == 1
    assert len(dist._fused_cache) == 1
    # shape change -> exactly one new trace
    dist.matmul(A_NP[:16], B_NP, round_idx=2)
    assert dist.trace_count == 2
    assert len(dist._fused_cache) == 2
    # back to the first shape: cached fn, still no retrace
    dist.matmul(a, B_NP, round_idx=3)
    assert dist.trace_count == 2
    assert out1.shape == (32, NOUT) and np.all(np.isfinite(out1))
    assert stats1.total_s > 0 and stats2.decode_s == 0.0


def test_ill_conditioned_threshold_schemes_do_not_default_to_fused():
    """MDS at paper scale (K=24, Vandermonde cond ~3e8) is past f32's
    reach — the f32 pinv masked decode would silently destroy the result,
    so the runtime must keep such schemes on the f64 loop decode unless
    the caller forces fused=True."""
    big = registry.build("mds", n_workers=30, k_blocks=24)
    assert big.supports_fused and not big.fused_decode_stable
    dist = DistributedMatmul("mds", n_workers=30, k_blocks=24, n_stragglers=3)
    assert not dist.use_fused                      # default: exact loop path
    forced = DistributedMatmul("mds", n_workers=30, k_blocks=24, fused=True)
    assert forced.use_fused                        # explicit opt-in honored
    # small-K MDS stays fused (well-conditioned); rateless is always stable
    assert registry.build("mds", n_workers=12, k_blocks=4).fused_decode_stable
    assert DistributedMatmul("mds", n_workers=12, k_blocks=4).use_fused
    assert registry.build("spacdc", n_workers=30, k_blocks=24,
                          t_colluding=3).fused_decode_stable


def test_fused_flag_validation_and_fallback():
    with pytest.raises(ValueError, match="fused"):
        DistributedMatmul("polynomial", 8, 2, p=2, q=2, fused=True)
    loop = DistributedMatmul("spacdc", 8, 4, fused=False)
    assert not loop.use_fused
    out, stats = loop.matmul(A_NP[:32], B_NP)
    assert stats.decode_s > 0            # loop path still times decode


def test_fused_and_loop_paths_agree():
    kw = dict(n_workers=10, k_blocks=4, t_colluding=1, n_stragglers=2, seed=3)
    fused = DistributedMatmul("spacdc", **kw)
    loop = DistributedMatmul("spacdc", fused=False, **kw)
    of, _ = fused.matmul(A_NP[:32], B_NP, round_idx=4)
    ol, _ = loop.matmul(A_NP[:32], B_NP, round_idx=4)
    np.testing.assert_allclose(of, ol, atol=1e-3, rtol=1e-3)


def test_spacdc_decode_matrix_cached_by_responder_tuple():
    code = registry.build("spacdc", n_workers=10, k_blocks=4)
    resp = [0, 2, 5, 7]
    m1 = code.decode_matrix(resp)
    m2 = code.decode_matrix(np.asarray(resp))
    assert m1 is m2                      # same object: cache hit
    info = code._decode_matrix_cached.cache_info()
    assert info.hits >= 1 and info.misses == 1
