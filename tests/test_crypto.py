"""ECC + MEA-ECC (paper §IV): group law, fast scalar multiplication vs the
double-and-add oracle, limb codec properties, keystream parity, and
bit-exactness of the limb-vectorized cipher against the legacy object-dtype
implementation (``crypto/ref.py``)."""

import hashlib

import numpy as np
import jax.numpy as jnp
import pytest

from repro.crypto import (CURVE_SECP256K1, MEAECC, generate_keypair,
                          shared_secret)
from repro.crypto.ecc import (CURVE_TOY, ECPoint, INFINITY, ephemeral_nonce,
                              keystream)
from repro.crypto import field as F
from repro.crypto.ref import LegacyFixedPointCodec, LegacyMEAECC

Q = CURVE_SECP256K1.q


class TestCurveGroupLaw:
    def test_points_on_curve(self):
        c = CURVE_TOY
        pts = [c.multiply(k, c.generator) for k in range(1, c.order)]
        assert all(c.contains(p) for p in pts)

    def test_commutativity(self):
        c = CURVE_TOY
        pts = [c.multiply(k, c.generator) for k in range(1, c.order)]
        for p in pts[:6]:
            for q in pts[:6]:
                assert c.add(p, q) == c.add(q, p)

    def test_associativity(self):
        c = CURVE_TOY
        pts = [c.multiply(k, c.generator) for k in range(1, 8)]
        for p in pts[:4]:
            for q in pts[:4]:
                for r in pts[:4]:
                    assert c.add(c.add(p, q), r) == c.add(p, c.add(q, r))

    def test_identity_and_inverse(self):
        c = CURVE_TOY
        p = c.multiply(3, c.generator)
        assert c.add(p, INFINITY) == p
        assert c.add(p, c.neg(p)).is_infinity

    def test_order(self):
        c = CURVE_TOY
        assert c.multiply(c.order, c.generator).is_infinity

    def test_scalar_mult_matches_repeated_add(self):
        c = CURVE_TOY
        acc = INFINITY
        for k in range(1, 10):
            acc = c.add(acc, c.generator)
            assert acc == c.multiply(k, c.generator)

    def test_singular_curve_rejected(self):
        from repro.crypto.ecc import EllipticCurve
        with pytest.raises(ValueError):
            EllipticCurve(q=17, a=0, b=0, gx=1, gy=1, order=1)


class TestFastScalarMultiply:
    """wNAF / Jacobian / fixed-base comb vs the affine double-and-add oracle."""

    def test_toy_exhaustive(self):
        c = CURVE_TOY
        base = c.multiply_naive(7, c.generator)
        for k in range(0, 2 * c.order + 1):
            assert c.multiply(k, c.generator) == \
                c.multiply_naive(k, c.generator), k
            assert c.multiply(k, base) == c.multiply_naive(k, base), k
            assert c.multiply_base(k) == c.multiply_naive(k, c.generator), k

    def test_secp256k1_vectors(self):
        c = CURVE_SECP256K1
        # known vector: 2·G (secp256k1 test vectors)
        assert c.multiply_base(2) == ECPoint(
            0xC6047F9441ED7D6D3045406E95C07CD85C778E4B8CEF3CA7ABAC09B95C709EE5,
            0x1AE168FEA63DC339A3C58419466CEAEEF7F632653266D0E1236431A950CFE52A)
        rng = np.random.default_rng(0)
        p = c.multiply_base(0xDEADBEEF)
        for k in [1, 2, 3, c.order - 1, c.order // 2,
                  *(int(rng.integers(1, 2**62)) ** 4 for _ in range(3))]:
            assert c.multiply_base(k) == c.multiply_naive(k, c.generator), k
            assert c.multiply(k, p) == c.multiply_naive(k, p), k

    def test_infinity_and_zero(self):
        c = CURVE_SECP256K1
        assert c.multiply(0, c.generator).is_infinity
        assert c.multiply_base(c.order).is_infinity
        assert c.multiply(5, INFINITY).is_infinity


class TestECDH:
    def test_shared_key_agreement(self):
        a = generate_keypair()
        b = generate_keypair()
        assert shared_secret(CURVE_SECP256K1, a, b.pk) == \
            shared_secret(CURVE_SECP256K1, b, a.pk)

    def test_distinct_keys(self):
        assert generate_keypair().sk != generate_keypair().sk

    def test_shared_point_cached(self):
        from repro.crypto.ecc import _cached_shared
        a, b = generate_keypair(), generate_keypair()
        before = _cached_shared.cache_info().hits
        s1 = shared_secret(CURVE_SECP256K1, a, b.pk)
        s2 = shared_secret(CURVE_SECP256K1, a, b.pk)
        assert s1 == s2
        assert _cached_shared.cache_info().hits > before


class TestNonceDerivation:
    def test_x_zero_is_a_legal_nonce(self):
        # x = 0 is a real affine coordinate on CURVE_TOY: y² = 2 has y = 6
        p = ECPoint(0, 6)
        assert CURVE_TOY.contains(p)
        assert ephemeral_nonce(p) == 0

    def test_infinity_rejected(self):
        with pytest.raises(ValueError):
            ephemeral_nonce(INFINITY)

    def test_keystream_returns_ndarray(self):
        ks = keystream(ECPoint(3, 5), 1, 9, Q)
        assert isinstance(ks, np.ndarray) and ks.dtype == np.uint64


class TestLimbField:
    def test_add_sub_match_bigint(self):
        rng = np.random.default_rng(0)
        fld = F.LimbField(Q)
        av = [int.from_bytes(rng.bytes(32), "big") % Q for _ in range(100)]
        bv = [int.from_bytes(rng.bytes(32), "big") % Q for _ in range(100)]
        a = np.stack([F.int_to_limbs(v, fld.n_limbs) for v in av])
        b = np.stack([F.int_to_limbs(v, fld.n_limbs) for v in bv])
        for got, want in zip(F.limbs_to_int(fld.add(a, b)),
                             [(x + y) % Q for x, y in zip(av, bv)]):
            assert int(got) == want
        for got, want in zip(F.limbs_to_int(fld.sub(a, b)),
                             [(x - y) % Q for x, y in zip(av, bv)]):
            assert int(got) == want

    def test_u64_view(self):
        fld = F.LimbField(Q)
        limbs = fld.from_int((1 << 200) + 12345, shape=(3,))
        view = F.as_u64(limbs)
        assert view.shape == (3, fld.n_limbs // 2)
        assert int(view[0, 0]) == 12345

    def test_roundtrip_int_limbs(self):
        for v in (0, 1, Q - 1, 1 << 255, 0xFFFFFFFF, 1 << 32):
            assert int(F.limbs_to_int(F.int_to_limbs(v % Q, 8))) == v % Q


# edge floats: zeros, subnormals, the ±3e38 clamp region, f32 extremes,
# exact halves (round-half-even), powers of two crossing limb boundaries
EDGE_F32 = np.array(
    [0.0, -0.0, 1.0, -1.0, 1.5, -1.5, 2.5 / 65536, 3.5 / 65536,
     -2.5 / 65536, -3.5 / 65536, 1 / 65536, -1 / 65536, 0.5 / 65536,
     2**-149, -2**-149, 1e-38, -1e-38, 3e38, -3e38, 3.4e38, -3.4e38,
     2.9e38, 65504.0, -65504.0, 2.0**24, 2.0**24 + 2, 2.0**31, 2.0**32,
     2.0**63, 2.0**64, -2.0**90, 123.456, -9876.543], np.float32)


class TestLimbCodec:
    def _codec(self):
        return F.FixedPointCodec(Q, 16)

    def test_embed_matches_legacy_bigint(self):
        rng = np.random.default_rng(1)
        xs = np.concatenate([EDGE_F32,
                             (rng.standard_normal(400) * 100).astype(np.float32),
                             (rng.standard_normal(100) * 1e37).astype(np.float32)])
        enc = self._codec().encode(xs)
        legacy = LegacyFixedPointCodec(Q, 16).encode(xs.astype(np.float64))
        for got, want in zip(F.limbs_to_int(enc), legacy):
            assert int(got) == int(want)

    def test_roundtrip_quantizes_exactly(self):
        codec = self._codec()
        dec = codec.decode(codec.encode(EDGE_F32))
        want = np.clip(np.round(EDGE_F32.astype(np.float64) * 2**16) / 2**16,
                       -3e38, 3e38).astype(np.float32)
        np.testing.assert_array_equal(dec, want)

    @pytest.mark.parametrize("dtype", ["float16", "bfloat16"])
    def test_half_precision_inputs(self, dtype):
        rng = np.random.default_rng(2)
        xs = np.asarray(jnp.asarray(rng.standard_normal(128) * 8, dtype))
        codec = self._codec()
        dec = codec.decode(codec.encode(xs))
        want = np.round(np.asarray(xs, np.float64) * 2**16) / 2**16
        np.testing.assert_array_equal(dec, want.astype(np.float32))

    def test_decode_matches_legacy_on_garbage(self):
        """Wrong-key decrypts see uniform field elements; the clamp path
        must match the legacy decoder bit-for-bit."""
        rng = np.random.default_rng(3)
        vals = [int.from_bytes(rng.bytes(32), "big") % Q for _ in range(256)]
        limbs = np.stack([F.int_to_limbs(v, 8) for v in vals])
        got = self._codec().decode(limbs)
        want = LegacyFixedPointCodec(Q, 16).decode(
            np.array(vals, dtype=object).reshape(-1))
        np.testing.assert_array_equal(got, want)

    def test_traced_codec_matches_numpy(self):
        """The in-jit (XLA) codec twins are bit-identical to the numpy
        reference across the edge sweep."""
        rng = np.random.default_rng(4)
        xs = np.concatenate([EDGE_F32,
                             (rng.standard_normal(300) * 50).astype(np.float32)])
        codec = self._codec()
        enc_np = codec.encode(xs)
        enc_tr = np.asarray(F.fixed_encode_traced(xs, Q, 16, 8))
        np.testing.assert_array_equal(enc_np, enc_tr)
        dec_tr = np.asarray(F.fixed_decode_traced(enc_np, Q, 16))
        np.testing.assert_array_equal(codec.decode(enc_np), dec_tr)

    def test_bits_codec_lossless_all_dtypes(self):
        rng = np.random.default_rng(5)
        bc = F.BitsCodec(Q)
        for dtype in (np.float32, np.float64, np.float16, np.int32, np.int8):
            arr = (rng.standard_normal((7, 5)) * 100).astype(dtype)
            out = bc.decode(bc.encode(arr), dtype, arr.shape)
            assert out.dtype == arr.dtype
            np.testing.assert_array_equal(out.view(np.uint8),
                                          arr.view(np.uint8))

    def test_small_modulus_rejected(self):
        with pytest.raises(ValueError):
            F.FixedPointCodec(CURVE_TOY.q, 16)
        with pytest.raises(ValueError):
            F.BitsCodec(CURVE_TOY.q)


class TestVectorizedKeystream:
    def test_sha256_blocks_match_hashlib(self):
        seed = hashlib.sha256(b"spacdc").digest()
        digests = F.sha256_counter_blocks(seed, np.arange(7, dtype=np.uint64))
        for c in range(7):
            want = hashlib.sha256(seed + int(c).to_bytes(8, "big")).digest()
            got = b"".join(int(x).to_bytes(4, "big") for x in digests[c])
            assert got == want

    @pytest.mark.parametrize("q", [Q, 17, (1 << 61) - 1])
    @pytest.mark.parametrize("n", [1, 4, 5, 37])
    def test_matches_scalar_reference(self, q, n):
        ks_vec = F.keystream_u64(12345, 67890, 7, n, q)
        ks_ref = keystream(ECPoint(12345, 67890), 7, n, q)
        np.testing.assert_array_equal(ks_vec, ks_ref)

    def test_traced_mask_matches_numpy(self):
        seed8 = F.seed_words(111, 222, 333)
        got = np.asarray(F.stream_mask_traced(seed8, 37, 8))
        words = F.keystream_u64(111, 222, 333, 37, Q)
        want = F.LimbField(Q).from_u64(words)
        np.testing.assert_array_equal(got, want)

    def test_nonce_changes_stream(self):
        a = F.keystream_u64(1, 2, 3, 16, Q)
        b = F.keystream_u64(1, 2, 4, 16, Q)
        assert (np.asarray(a) != np.asarray(b)).any()

    # ---- block-boundary coverage: each SHA-256 block yields 4 u64 words,
    # so every n_words % 4 != 0 exercises a trailing partial block ----
    @pytest.mark.parametrize("n", [1, 2, 3, 5, 6, 7, 9, 11, 41])
    def test_partial_block_matches_hashlib_oracle(self, n):
        x, y, nonce = 111, 222, 333
        seed = hashlib.sha256(f"{x}:{y}:{nonce}".encode()).digest()
        want = []
        for c in range(-(-n // 4)):              # trailing block included
            d = hashlib.sha256(seed + c.to_bytes(8, "big")).digest()
            for j in range(4):                   # w = digest_hi32<<32|lo32
                want.append(int.from_bytes(d[8 * j:8 * j + 8], "big"))
        got = F.keystream_u64(x, y, nonce, n, Q)
        assert got.shape == (n,)
        np.testing.assert_array_equal(got, np.asarray(want[:n], np.uint64))

    @pytest.mark.parametrize("n", [0, 1, 3, 5, 37, 63])
    def test_traced_twin_partial_blocks(self, n):
        seed8 = F.seed_words(7, 8, 9)
        got = np.asarray(F.stream_mask_traced(seed8, n, 8))
        want = F.LimbField(Q).from_u64(F.keystream_u64(7, 8, 9, n, Q))
        assert got.shape == (n, 8)
        np.testing.assert_array_equal(got, want.reshape(n, 8))

    def test_prefix_stable_across_block_boundary(self):
        # pad-to-bucket-then-slice (the cipher cores' convention) is only
        # sound because the counter PRF is a prefix-stable stream
        long = F.keystream_u64(5, 6, 7, 23, Q)
        for n in (1, 3, 4, 5, 8, 19, 23):
            np.testing.assert_array_equal(F.keystream_u64(5, 6, 7, n, Q),
                                          long[:n])


class TestMEAECC:
    @pytest.mark.parametrize("mode", ["paper", "stream"])
    def test_roundtrip_exact(self, mode):
        rng = np.random.default_rng(0)
        m = (rng.standard_normal((6, 5)) * 100).astype(np.float32)
        mea = MEAECC(mode=mode)
        out = mea.secure_channel_roundtrip(m)
        np.testing.assert_allclose(out, np.round(m * 2**16) / 2**16, atol=0)

    @pytest.mark.parametrize("mode", ["paper", "stream"])
    def test_bit_exact_parity_with_legacy(self, mode):
        """The tentpole contract: same ciphertext ints, same decrypted
        floats as the object-dtype oracle, for fixed key and nonce."""
        rng = np.random.default_rng(1)
        w = generate_keypair(sk=0xABCDEF123456789)
        for arr in [(rng.standard_normal((16, 8)) * 100).astype(np.float32),
                    EDGE_F32]:
            mea, leg = MEAECC(mode=mode), LegacyMEAECC(mode=mode)
            c = mea.encrypt(arr, w.pk, k=99991)
            cl = leg.encrypt(arr, w.pk, k=99991)
            assert c.ephemeral == cl.ephemeral
            for got, want in zip(F.limbs_to_int(c.payload),
                                 cl.payload.reshape(-1)):
                assert int(got) == int(want)
            np.testing.assert_array_equal(mea.decrypt(c, w),
                                          leg.decrypt(cl, w))

    def test_ciphertext_hides_plaintext(self):
        rng = np.random.default_rng(1)
        m = rng.standard_normal((4, 4)).astype(np.float32)
        mea = MEAECC(mode="stream")
        w = generate_keypair()
        c1 = mea.encrypt(m, w.pk, k=12345)
        c2 = mea.encrypt(np.zeros_like(m), w.pk, k=12345)
        # same key/nonce, different plaintext -> payload differs elementwise
        v1, v2 = F.limbs_to_int(c1.payload), F.limbs_to_int(c2.payload)
        assert all(int(a) != int(b) for a, b in zip(v1[:4], v2[:4]))

    def test_wrong_key_fails_to_decrypt(self):
        rng = np.random.default_rng(2)
        m = rng.standard_normal((3, 3)).astype(np.float32)
        mea = MEAECC(mode="paper")
        w1, w2 = generate_keypair(), generate_keypair()
        ct = mea.encrypt(m, w1.pk)
        wrong = mea.decrypt(ct, w2)
        assert not np.allclose(wrong, m, atol=1e-3)

    def test_keystream_deterministic(self):
        a = generate_keypair(sk=123456789)
        ks1 = keystream(a.pk, 7, 16, Q)
        ks2 = keystream(a.pk, 7, 16, Q)
        ks3 = keystream(a.pk, 8, 16, Q)
        assert np.array_equal(ks1, ks2) and not np.array_equal(ks1, ks3)

    @pytest.mark.parametrize("dtype", [np.float32, np.float64, np.int32])
    def test_bits_codec_transport_bit_identical(self, dtype):
        rng = np.random.default_rng(3)
        arr = (rng.standard_normal((13, 7)) * 50).astype(dtype)
        mea = MEAECC(mode="stream", codec="bits")
        w = generate_keypair()
        out = mea.decrypt(mea.encrypt(arr, w.pk, k=777), w)
        assert out.dtype == arr.dtype
        np.testing.assert_array_equal(out.view(np.uint8), arr.view(np.uint8))

    def test_static_channel_and_nonces(self):
        """sender= reuses the cached ECDH point; distinct nonces give
        distinct ciphertexts that both decrypt exactly."""
        rng = np.random.default_rng(4)
        m = rng.standard_normal((8, 4)).astype(np.float32)
        mea = MEAECC(mode="stream", codec="bits")
        master, w = generate_keypair(), generate_keypair()
        c1 = mea.encrypt(m, w.pk, sender=master, nonce=1)
        c2 = mea.encrypt(m, w.pk, sender=master, nonce=2)
        assert c1.ephemeral == master.pk
        v1, v2 = F.limbs_to_int(c1.payload), F.limbs_to_int(c2.payload)
        assert any(int(a) != int(b) for a, b in zip(v1, v2))
        np.testing.assert_array_equal(mea.decrypt(c1, w), m)
        np.testing.assert_array_equal(mea.decrypt(c2, w), m)

    def test_decrypt_honors_ciphertext_codec(self):
        """Ciphertexts are self-describing: an instance configured with one
        codec decrypts a ciphertext produced under the other."""
        rng = np.random.default_rng(6)
        arr = rng.standard_normal((5, 3)).astype(np.float32)
        w = generate_keypair(sk=171717)
        ct_bits = MEAECC(mode="stream", codec="bits").encrypt(arr, w.pk, k=9)
        out = MEAECC(mode="stream").decrypt(ct_bits, w)     # fixed instance
        np.testing.assert_array_equal(out, arr)
        ct_fixed = MEAECC(mode="paper").encrypt(arr, w.pk, k=9)
        out2 = MEAECC(mode="paper", codec="bits").decrypt(ct_fixed, w)
        np.testing.assert_array_equal(
            out2, MEAECC(mode="paper").decrypt(ct_fixed, w))

    def test_static_stream_channel_requires_nonce(self):
        """nonce=None on a static stream channel would reuse one keystream
        for every message (two-time pad) — rejected."""
        mea = MEAECC(mode="stream", codec="bits")
        master, w = generate_keypair(), generate_keypair()
        with pytest.raises(ValueError):
            mea.encrypt(np.ones(4, np.float32), w.pk, sender=master)

    @pytest.mark.parametrize("force", [False, True])
    def test_use_kernel_tristate_parity(self, force):
        """Pallas kernel (interpret off-TPU) and XLA twin produce identical
        ciphertexts and plaintexts."""
        rng = np.random.default_rng(5)
        m = rng.standard_normal((6, 4)).astype(np.float32)
        w = generate_keypair(sk=424242)
        base = MEAECC(mode="paper")
        forced = MEAECC(mode="paper", use_kernel=force)
        c0 = base.encrypt(m, w.pk, k=31337)
        c1 = forced.encrypt(m, w.pk, k=31337)
        np.testing.assert_array_equal(c0.payload, c1.payload)
        np.testing.assert_array_equal(base.decrypt(c0, w),
                                      forced.decrypt(c1, w))
