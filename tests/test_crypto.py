import numpy as np
import pytest

from repro.crypto import (CURVE_SECP256K1, MEAECC, generate_keypair,
                          shared_secret)
from repro.crypto.ecc import CURVE_TOY, INFINITY, keystream


class TestCurveGroupLaw:
    def test_points_on_curve(self):
        c = CURVE_TOY
        pts = [c.multiply(k, c.generator) for k in range(1, c.order)]
        assert all(c.contains(p) for p in pts)

    def test_commutativity(self):
        c = CURVE_TOY
        pts = [c.multiply(k, c.generator) for k in range(1, c.order)]
        for p in pts[:6]:
            for q in pts[:6]:
                assert c.add(p, q) == c.add(q, p)

    def test_associativity(self):
        c = CURVE_TOY
        pts = [c.multiply(k, c.generator) for k in range(1, 8)]
        for p in pts[:4]:
            for q in pts[:4]:
                for r in pts[:4]:
                    assert c.add(c.add(p, q), r) == c.add(p, c.add(q, r))

    def test_identity_and_inverse(self):
        c = CURVE_TOY
        p = c.multiply(3, c.generator)
        assert c.add(p, INFINITY) == p
        assert c.add(p, c.neg(p)).is_infinity

    def test_order(self):
        c = CURVE_TOY
        assert c.multiply(c.order, c.generator).is_infinity

    def test_scalar_mult_matches_repeated_add(self):
        c = CURVE_TOY
        acc = INFINITY
        for k in range(1, 10):
            acc = c.add(acc, c.generator)
            assert acc == c.multiply(k, c.generator)

    def test_singular_curve_rejected(self):
        from repro.crypto.ecc import EllipticCurve
        with pytest.raises(ValueError):
            EllipticCurve(q=17, a=0, b=0, gx=1, gy=1, order=1)


class TestECDH:
    def test_shared_key_agreement(self):
        a = generate_keypair()
        b = generate_keypair()
        assert shared_secret(CURVE_SECP256K1, a, b.pk) == \
            shared_secret(CURVE_SECP256K1, b, a.pk)

    def test_distinct_keys(self):
        assert generate_keypair().sk != generate_keypair().sk


class TestMEAECC:
    @pytest.mark.parametrize("mode", ["paper", "stream"])
    def test_roundtrip_exact(self, mode):
        rng = np.random.default_rng(0)
        m = (rng.standard_normal((6, 5)) * 100).astype(np.float32)
        mea = MEAECC(mode=mode)
        out = mea.secure_channel_roundtrip(m)
        np.testing.assert_allclose(out, np.round(m * 2**16) / 2**16, atol=0)

    def test_ciphertext_hides_plaintext(self):
        rng = np.random.default_rng(1)
        m = rng.standard_normal((4, 4)).astype(np.float32)
        mea = MEAECC(mode="stream")
        w = generate_keypair()
        c1 = mea.encrypt(m, w.pk, k=12345)
        c2 = mea.encrypt(np.zeros_like(m), w.pk, k=12345)
        # same key/nonce, different plaintext -> payload differs elementwise
        assert all(int(a) != int(b) for a, b in
                   zip(c1.payload.reshape(-1)[:4], c2.payload.reshape(-1)[:4]))

    def test_wrong_key_fails_to_decrypt(self):
        rng = np.random.default_rng(2)
        m = rng.standard_normal((3, 3)).astype(np.float32)
        mea = MEAECC(mode="paper")
        w1, w2 = generate_keypair(), generate_keypair()
        ct = mea.encrypt(m, w1.pk)
        wrong = mea.decrypt(ct, w2)
        assert not np.allclose(wrong, m, atol=1e-3)

    def test_keystream_deterministic(self):
        a = generate_keypair(sk=123456789)
        ks1 = keystream(a.pk, 7, 16, CURVE_SECP256K1.q)
        ks2 = keystream(a.pk, 7, 16, CURVE_SECP256K1.q)
        ks3 = keystream(a.pk, 8, 16, CURVE_SECP256K1.q)
        assert ks1 == ks2 and ks1 != ks3
