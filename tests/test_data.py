import numpy as np
import jax.numpy as jnp

from repro.configs import tiny_config
from repro.configs.base import ShapeSpec
from repro.data import TokenPipeline, synthetic_mnist
from repro.data.pipeline import make_batch


def test_pipeline_deterministic():
    p = TokenPipeline(vocab_size=100, seq_len=16, global_batch=4, seed=3)
    b1, b2 = p.batch_at(7), p.batch_at(7)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = p.batch_at(8)
    assert (np.asarray(b1["tokens"]) != np.asarray(b3["tokens"])).any()


def test_targets_are_shifted_tokens():
    p = TokenPipeline(vocab_size=50, seq_len=8, global_batch=2)
    b = p.batch_at(0)
    np.testing.assert_array_equal(np.asarray(b["targets"])[:, :-1],
                                  np.asarray(b["tokens"])[:, 1:])


def test_tokens_in_range():
    p = TokenPipeline(vocab_size=37, seq_len=64, global_batch=3)
    t = np.asarray(p.batch_at(0)["tokens"])
    assert t.min() >= 0 and t.max() < 37


def test_synthetic_mnist_learnable():
    xtr, ytr, xte, yte = synthetic_mnist(n_train=2048, n_test=512)
    assert xtr.shape == (2048, 784)
    # linear probe via least squares gets well above chance
    onehot = np.eye(10)[ytr]
    w, *_ = np.linalg.lstsq(xtr, onehot, rcond=None)
    acc = (xte @ w).argmax(1) == yte
    assert acc.mean() > 0.7


def test_make_batch_covers_decode():
    cfg = tiny_config("qwen2-7b")
    b = make_batch(cfg, ShapeSpec("d", 32, 4, "decode"))
    assert b["tokens"].shape == (4, 1)
