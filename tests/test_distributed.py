"""Multi-device integration tests: the sharded coded train step and the
seq-sharded decode cache EXECUTE correctly on a real (forced-host) mesh.

jax locks the device count at first init, so these run in subprocesses with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (2×4 data×model mesh).
"""

import os
import subprocess
import sys

import pytest

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(script: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = REPO_SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


TRAIN_SCRIPT = r"""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import tiny_config
from repro.core import BerrutGradientCode
from repro.data.pipeline import TokenPipeline
from repro.dist.sharding import tree_shardings
from repro.launch.mesh import make_test_mesh, use_mesh
from repro.launch.steps import build_train_step
from repro.models import build_model
from repro.optim import adamw

mesh = make_test_mesh((2, 4), ("data", "model"))
cfg = tiny_config("qwen2-7b")
import dataclasses
cfg = dataclasses.replace(cfg, pad_heads_to=4)
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
opt = adamw(3e-3, weight_decay=0.0)
state = opt.init(params)
nb = 2
gcode = BerrutGradientCode(nb, nb)
step = build_train_step(model, opt, accum=2, gcode=gcode, dp_axes="data")

p_shard = tree_shardings(model.param_specs(), mesh, jax.eval_shape(model.init, jax.random.PRNGKey(0)))
params = jax.device_put(params, p_shard)
state = jax.device_put(state, jax.tree.map(lambda s: s, __import__("repro.optim.optimizers", fromlist=["OptState"]).OptState(
    NamedSharding(mesh, P()), p_shard, p_shard)))
pipe = TokenPipeline(cfg.vocab_size, 32, nb * 2 * 2)
with use_mesh(mesh):
    jstep = jax.jit(step)
    losses = []
    for i in range(8):
        mask = np.ones(nb, np.float32)
        if i % 3 == 2:
            mask[i % nb] = 0.0          # straggler
        batch = jax.device_put(pipe.batch_at(i),
                               {k: NamedSharding(mesh, P("data", None))
                                for k in ("tokens", "targets")})
        params, state, m = jstep(params, state, batch, jnp.asarray(mask))
        losses.append(float(m["loss"]))
assert all(np.isfinite(losses)), losses
assert losses[-1] < losses[0], losses
print("SHARDED_TRAIN_OK", round(losses[0], 3), "->", round(losses[-1], 3))
"""


DECODE_SCRIPT = r"""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import tiny_config
from repro.dist.sharding import tree_shardings
from repro.launch.mesh import make_test_mesh, use_mesh
from repro.models import build_model
import dataclasses

mesh = make_test_mesh((2, 4), ("data", "model"))
cfg = dataclasses.replace(tiny_config("qwen3-14b"), pad_heads_to=4)
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
rng = np.random.default_rng(0)
toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 6)), jnp.int32)

# single-device reference
ref_cache = model.init_cache(2, 8)
ref = []
for t in range(6):
    logits, ref_cache = model.decode_step(params, ref_cache, toks[:, t:t+1], t)
    ref.append(np.asarray(logits[:, 0], np.float32))

# sharded: cache seq dim over model, batch over data
with use_mesh(mesh):
    c_shapes = jax.eval_shape(lambda: model.init_cache(2, 8))
    c_shard = tree_shardings(model.cache_specs(), mesh, c_shapes)
    cache = jax.device_put(model.init_cache(2, 8), c_shard)
    p_shard = tree_shardings(model.param_specs(), mesh,
                             jax.eval_shape(model.init, jax.random.PRNGKey(0)))
    sparams = jax.device_put(params, p_shard)
    step = jax.jit(lambda p, c, t, i: model.decode_step(p, c, t, i))
    for t in range(6):
        logits, cache = step(sparams, cache, toks[:, t:t+1], t)
        got = np.asarray(logits[:, 0], np.float32)
        err = np.abs(got - ref[t]).max()
        assert err < 0.25, (t, err)
print("SHARDED_DECODE_OK")
"""


@pytest.mark.slow
def test_sharded_coded_train_executes():
    out = _run(TRAIN_SCRIPT)
    assert "SHARDED_TRAIN_OK" in out


@pytest.mark.slow
def test_sharded_decode_matches_single_device():
    out = _run(DECODE_SCRIPT)
    assert "SHARDED_DECODE_OK" in out
