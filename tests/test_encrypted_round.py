"""The one-dispatch encrypted round (``kernels.encrypted_round``): output
bit-parity with the plain pipeline, ciphertext limb parity with the staged
cipher cores, and bit-exactness of the specialized bits-codec wires
against the general carry-chain path (adversarial Ψ included)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.crypto import CURVE_SECP256K1
from repro.crypto import field as F
from repro.kernels import ops, ref
from repro.kernels.encrypted_round import wire_roundtrip

Q = CURVE_SECP256K1.q
L = 8
rng = np.random.default_rng(0)


def _psi_limbs(psi_ints):
    return jnp.asarray(np.stack([np.asarray(F.int_to_limbs(p, L), np.uint32)
                                 for p in psi_ints]))


def _materials(n, mode, seed):
    r = np.random.default_rng(seed)
    if mode == "stream":
        return jnp.asarray(r.integers(0, 2 ** 32, (n, 8), dtype=np.uint32))
    return _psi_limbs([int.from_bytes(r.bytes(32), "big") % (Q - 1) + 1
                       for _ in range(n)])


def _operands(n, j, blk, d, n_out):
    return (jnp.asarray(rng.standard_normal((n, j)), jnp.float32),
            jnp.asarray(rng.standard_normal((j, blk, d)), jnp.float32),
            jnp.asarray(rng.standard_normal((d, n_out)), jnp.float32))


class TestEncryptedCodedMatmul:
    @pytest.mark.parametrize("mode", ["stream", "paper"])
    @pytest.mark.parametrize("force_kernel", [False, True])
    def test_bit_identical_to_plain_and_oracle(self, mode, force_kernel):
        n, j, blk, d, n_out = (6, 5, 4, 8, 16) if force_kernel \
            else (10, 8, 6, 12, 24)
        w, blocks, rhs = _operands(n, j, blk, d, n_out)
        mo, mb = _materials(n, mode, 1), _materials(n, mode, 2)
        plain = np.asarray(ref.coded_matmul(w, blocks, rhs))
        enc = ops.encrypted_coded_matmul(w, blocks, rhs, mo, mb, q=Q,
                                         mode=mode, force_kernel=force_kernel)
        oracle = ref.encrypted_coded_matmul(w, blocks, rhs, mo, mb, q=Q,
                                            mode=mode)
        np.testing.assert_array_equal(np.asarray(enc), plain)
        np.testing.assert_array_equal(np.asarray(oracle), plain)

    @pytest.mark.parametrize("mode", ["stream", "paper"])
    def test_wire_ciphertext_matches_staged_core(self, mode):
        """The fused round's in-trace ciphertexts are the SAME bits the
        staged ``mea_encrypt_core`` dispatch produces, channel by channel
        — the fusion moves the wire, it doesn't change it."""
        n, j, blk, d, n_out = 5, 4, 3, 8, 6
        w, blocks, rhs = _operands(n, j, blk, d, n_out)
        mo, mb = _materials(n, mode, 3), _materials(n, mode, 4)
        _, ct_out, ct_back = ops.encrypted_coded_matmul(
            w, blocks, rhs, mo, mb, q=Q, mode=mode, force_kernel=False,
            return_wire=True)
        coded = jnp.dot(w, blocks.reshape(j, -1),
                        precision=jax.lax.Precision.HIGHEST).reshape(n, blk, d)
        words = jax.lax.bitcast_convert_type(coded.reshape(n, -1), jnp.uint32)
        for i in range(n):
            want = ops.mea_encrypt_core(words[i], mo[i], q=Q, frac_bits=16,
                                        mode=mode, codec="bits",
                                        use_kernel=False, interpret=True,
                                        n_limbs=L)
            np.testing.assert_array_equal(np.asarray(ct_out[i]),
                                          np.asarray(want))
        assert ct_back.shape == (n, blk * n_out, L)

    def test_stream_needs_wide_modulus(self):
        x = jnp.zeros((2, 8), jnp.float32)
        with pytest.raises(ValueError, match="64-bit"):
            wire_roundtrip(x, jnp.zeros((2, 8), jnp.uint32), q=(1 << 61) - 1,
                           mode="stream")


class TestSpecializedWires:
    """The fast XLA wires vs the general Pallas/carry-chain path."""

    @pytest.mark.parametrize("psi_int", [
        1, 2 ** 32 - 1, 2 ** 32, 2 ** 64 - 1, Q // 2, Q - 1,
        Q - 2 ** 32 + 1, Q - 2 ** 32, Q - 2 ** 32 - 1,   # reduction corner
    ])
    def test_paper_wire_exact_vs_general(self, psi_int):
        psi = _psi_limbs([psi_int])
        x = jnp.asarray(rng.standard_normal((1, 256)), jnp.float32)
        # plant payload words right at the single-limb overflow threshold
        wds = np.asarray(jax.lax.bitcast_convert_type(x, jnp.uint32)).copy()
        thr = (Q - psi_int) % (2 ** 32)
        wds[0, :4] = [thr % 2 ** 32, (thr - 1) % 2 ** 32,
                      (thr + 1) % 2 ** 32, 2 ** 32 - 1]
        x = jax.lax.bitcast_convert_type(jnp.asarray(wds), jnp.float32)
        out_s, ct_s = wire_roundtrip(x, psi, q=Q, mode="paper",
                                     use_kernel=False, return_ct=True)
        out_g, ct_g = wire_roundtrip(x, psi, q=Q, mode="paper",
                                     use_kernel=True, interpret=True,
                                     return_ct=True)
        np.testing.assert_array_equal(np.asarray(ct_s), np.asarray(ct_g))
        np.testing.assert_array_equal(
            np.asarray(jax.lax.bitcast_convert_type(out_s, jnp.uint32)),
            np.asarray(jax.lax.bitcast_convert_type(out_g, jnp.uint32)))

    def test_stream_narrow_wire_exact_vs_general(self):
        seeds = _materials(4, "stream", 5)
        x = jnp.asarray(rng.standard_normal((4, 512)), jnp.float32)
        out_n, ct_n = wire_roundtrip(x, seeds, q=Q, mode="stream",
                                     use_kernel=False, return_ct=True)
        out_g, ct_g = wire_roundtrip(x, seeds, q=Q, mode="stream",
                                     use_kernel=True, interpret=True,
                                     return_ct=True)
        np.testing.assert_array_equal(np.asarray(ct_n), np.asarray(ct_g))
        np.testing.assert_array_equal(np.asarray(out_n), np.asarray(out_g))

    @pytest.mark.parametrize("mode", ["stream", "paper"])
    def test_roundtrip_is_bit_identity(self, mode):
        x = jnp.asarray(rng.standard_normal((3, 100)) * 1e20, jnp.float32)
        out = wire_roundtrip(x, _materials(3, mode, 6), q=Q, mode=mode)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(x))


class TestFusedWire:
    @pytest.mark.parametrize("mode", ["stream", "paper"])
    @pytest.mark.parametrize("w", [1, 5, 1000, 1025])   # off-bucket sizes
    def test_standalone_wire_identity(self, mode, w):
        words = jnp.asarray(
            rng.integers(0, 2 ** 32, (3, w), dtype=np.uint32))
        out = ops.fused_wire(words, _materials(3, mode, 7), q=Q, mode=mode,
                             force_kernel=False)
        assert out.shape == (3, w)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(words))
