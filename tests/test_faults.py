"""Fault injection + defended rounds: determinism, screening, retries,
degradation, health, and the transport-robustness satellites.

The exclusion tests mirror BENCH_faults' acceptance shape: a corrupted
responder must be provably excluded (its decode-mask bit cleared), not
averaged into the output — on plain AND ``encrypt="real"`` rounds.
"""

import json
import threading
import time

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:    # container without hypothesis: deterministic fallback
    from _hypothesis_fallback import given, settings, strategies as st

from repro.api import (ClusterSpec, CodeSpec, CryptoSpec, FaultSpec,
                       PrivacySpec, Session, StragglerSpec, TransportSpec,
                       WaitSpec)
from repro.runtime import (DegradedRoundError, FaultInjectingTransport,
                           ResultDropped, ThreadTransport, WorkerHealth,
                           plan_faults, screen_responders)
from repro.runtime.straggler import StragglerModel

SET = dict(max_examples=20, deadline=None)


def _mats(seed=42, m=48, d=32, n_out=16):
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((m, d)).astype(np.float32)
    b = rng.standard_normal((d, n_out)).astype(np.float32)
    return a, b


def _spec(**over):
    kw = dict(
        code=CodeSpec(scheme="spacdc", n_workers=24, k_blocks=4,
                      extra={"fh_degree": 3}),
        privacy=PrivacySpec(t_colluding=2, noise_scale=0.01),
        straggler=StragglerSpec(n_stragglers=3), seed=11)
    kw.update(over)
    return ClusterSpec(**kw)


# ---------------------------------------------------------------- FaultSpec

def test_fault_spec_json_roundtrip():
    fs = FaultSpec(crash_rate=0.1, corrupt_rate=0.05, corrupt_mode="bitflip",
                   handle=True, max_retries=3, seed=99)
    back = FaultSpec.from_dict(json.loads(json.dumps(fs.to_dict())))
    assert back == fs
    spec = _spec(fault=fs)
    spec2 = ClusterSpec.from_dict(json.loads(json.dumps(spec.to_dict())))
    assert spec2.fault == fs


@pytest.mark.parametrize("bad", [
    dict(crash_rate=1.5),
    dict(drop_rate=-0.1),
    dict(corrupt_mode="garbage"),
    dict(corrupt_scale=0.0),
    dict(max_retries=-1),
    dict(backoff_s=0.1, backoff_cap_s=0.01),
    dict(worker_timeout_s=0.0),
    dict(residual_threshold=0.0),
    dict(norm_factor=1.0),
    dict(quarantine_after=0),
])
def test_fault_spec_rejects(bad):
    with pytest.raises(ValueError, match="fault:"):
        FaultSpec(**bad)


def test_cluster_validate_rejects_bad_fault_combos():
    fault = FaultSpec(handle=True)
    with pytest.raises(ValueError, match="pair-coded"):
        _spec(code=CodeSpec(scheme="polynomial", n_workers=8, k_blocks=4),
              privacy=PrivacySpec(), fault=fault).validate()
    with pytest.raises(ValueError, match="error_target"):
        _spec(wait=WaitSpec(policy="error_target", eps=1e-2),
              fault=fault).validate()
    with pytest.raises(ValueError, match="crypto.fused"):
        _spec(crypto=CryptoSpec(encrypt="real", fused=True),
              fault=fault).validate()


# ------------------------------------------------------------- determinism

@settings(**SET)
@given(seed=st.integers(0, 2**16), round_idx=st.integers(0, 500))
def test_plan_faults_deterministic(seed, round_idx):
    fault = FaultSpec(crash_rate=0.2, drop_rate=0.1, corrupt_rate=0.2,
                      delay_spike_rate=0.1)
    p1 = plan_faults(fault, seed, round_idx, 16)
    p2 = plan_faults(fault, seed, round_idx, 16)
    for f in ("crash", "drop", "corrupt", "spike_s"):
        np.testing.assert_array_equal(getattr(p1, f), getattr(p2, f))
    # crash/drop/corrupt are mutually exclusive per worker
    both = (p1.crash & p1.drop) | (p1.crash & p1.corrupt) | \
        (p1.drop & p1.corrupt)
    assert not both.any()


def test_plan_faults_varies_with_round():
    fault = FaultSpec(crash_rate=0.3, corrupt_rate=0.3)
    plans = [plan_faults(fault, 7, r, 32) for r in range(20)]
    crash_sets = {tuple(np.flatnonzero(p.crash)) for p in plans}
    assert len(crash_sets) > 1, "every round drew the identical fault plan"


def test_injection_identical_across_backends():
    """The fault plan (and thus which workers crash/corrupt) is a pure
    function of (seed, round) — the wrapped backend doesn't matter."""
    fault = FaultSpec(crash_rate=0.25, corrupt_rate=0.25, seed=3)
    n = 12
    strag = StragglerModel(n_workers=n, n_stragglers=0, seed=0,
                           delay_s=0.0)
    from repro.runtime.transport import VirtualClockTransport
    virt = FaultInjectingTransport(VirtualClockTransport(strag), fault, 3)
    thr_inner = ThreadTransport(n, StragglerModel(
        n_workers=n, n_stragglers=0, seed=0, delay_s=0.0))
    thr = FaultInjectingTransport(thr_inner, fault, 3)
    try:
        arrived = {}
        for name, tr in (("virtual", virt), ("threads", thr)):
            h = tr.submit_round([np.float32(i) for i in range(n)],
                                lambda x: x * 2, 5, t_compute=1e-4)
            evs = list(h.events())
            h.finish()
            arrived[name] = sorted(e.worker for e in evs)
        assert arrived["virtual"] == arrived["threads"]
        plan = plan_faults(fault, 3, 5, n)
        expect = sorted(set(range(n)) - set(np.flatnonzero(plan.crash)))
        assert arrived["virtual"] == expect
    finally:
        thr_inner.close()


# ----------------------------------------------------------- injector paths

def test_injector_drop_and_corrupt_virtual():
    fault = FaultSpec(drop_rate=0.5, corrupt_rate=0.3, corrupt_scale=1e3,
                      seed=0)
    n = 16
    strag = StragglerModel(n_workers=n, n_stragglers=0, seed=0, delay_s=0.0)
    from repro.runtime.transport import VirtualClockTransport
    tr = FaultInjectingTransport(VirtualClockTransport(strag), fault, 0)
    shards = [np.full((4,), float(i), np.float32) for i in range(n)]
    h = tr.submit_round(shards, lambda x: x + 1.0, 0, t_compute=1e-4)
    plan = plan_faults(fault, 0, 0, n)
    assert plan.drop.any() and plan.corrupt.any()
    for ev in h.events():
        w = ev.worker
        if plan.drop[w]:
            with pytest.raises(ResultDropped):
                h.result(w)
        elif plan.corrupt[w]:
            got = h.result(w)
            assert not np.allclose(got, shards[w] + 1.0)
        else:
            np.testing.assert_array_equal(h.result(w), shards[w] + 1.0)
    h.finish()


# --------------------------------------------- screening / mask-bit proofs

def _proof_spec(encrypt=None, cipher_mode="stream"):
    """Corrupt-only, no stragglers, no retries: every worker responds and
    every corrupted responder must end with its slot bit cleared."""
    return _spec(
        straggler=StragglerSpec(n_stragglers=0),
        crypto=CryptoSpec(encrypt=encrypt, cipher_mode=cipher_mode),
        fault=FaultSpec(corrupt_rate=0.25, corrupt_scale=1e3, handle=True,
                        max_retries=0, seed=5))


@pytest.mark.parametrize("encrypt,cipher_mode", [
    (None, "stream"), ("real", "stream"), ("real", "paper")])
def test_corrupted_responder_mask_bit_cleared(encrypt, cipher_mode):
    a, b = _mats()
    ref = a @ b
    spec = _proof_spec(encrypt, cipher_mode)
    plan = plan_faults(spec.fault, spec.fault.seed, 0, spec.code.n_workers)
    corrupted = set(int(w) for w in np.flatnonzero(plan.corrupt))
    assert corrupted, "seed must inject at least one corrupter in round 0"
    with Session(spec) as s:
        out, stats = s.matmul(a, b)
    # provably excluded: the exact corrupted set, nothing else; with
    # max_retries=0 and the identity assignment, worker w held slot w,
    # so its decode-mask bit must be cleared
    assert set(stats.excluded) == corrupted
    for w in corrupted:
        assert stats.decode_mask[w] == 0
    rel = np.linalg.norm(out - ref) / np.linalg.norm(ref)
    assert rel < 1e-2, f"corruption leaked into the decode: rel={rel:.3e}"


def test_clean_output_bit_identical_plain_vs_real():
    """The bits codec is lossless: a clean defended round decodes to the
    SAME float32 output whether shards travelled in the clear or as
    genuine ciphertexts — in both cipher modes."""
    a, b = _mats()
    outs = []
    for encrypt, mode in ((None, "stream"), ("real", "stream"),
                          ("real", "paper")):
        spec = _spec(crypto=CryptoSpec(encrypt=encrypt, cipher_mode=mode),
                     fault=FaultSpec(handle=True))
        with Session(spec) as s:
            out, stats = s.matmul(a, b)
        assert stats.excluded == () and stats.retries == 0
        outs.append(np.asarray(out))
    np.testing.assert_array_equal(outs[0], outs[1])
    np.testing.assert_array_equal(outs[0], outs[2])


def test_tampered_ciphertext_caught_like_plain_corruption():
    """Ciphertext tampering on the wire and plaintext corruption at the
    same (seed, round) evict the same workers — screening sees through
    the cipher layer (tampered limbs decrypt to garbage that the
    norm/residual stages reject identically)."""
    a, b = _mats()
    excl = {}
    for encrypt in (None, "real"):
        spec = _proof_spec(encrypt)
        with Session(spec) as s:
            out, stats = s.matmul(a, b)
        excl[encrypt] = set(stats.excluded)
    assert excl[None] == excl["real"] and excl[None]


def test_screen_responders_norm_stage_handles_many_corrupters():
    """The regime LOO alone can't separate: several corrupters pollute
    every leave-one-out prediction, but the median row norm stays at
    signal scale."""
    from repro.core import registry
    sch = registry.build("spacdc", n_workers=20, k_blocks=4, t_colluding=2,
                         noise_scale=0.01, seed=1)
    rng = np.random.default_rng(0)
    a, b = _mats(seed=1)
    enc = np.asarray(sch.encode(a))
    results = np.einsum("nij,jk->nik", enc, b)
    bad = [2, 7, 11, 15]
    for w in bad:
        results[w] = results[w] * 1e3 + rng.standard_normal(
            results[w].shape).astype(np.float32) * 1e3
    mask = np.ones(20, np.float32)
    clean_mask, excluded, _ = screen_responders(
        sch, results, mask, max_exclude=10)
    assert set(excluded) == set(bad)
    assert all(clean_mask[w] == 0.0 for w in bad)


def test_screen_responders_clean_round_no_false_positives():
    from repro.core import registry
    sch = registry.build("spacdc", n_workers=24, k_blocks=6, t_colluding=2,
                         noise_scale=0.05, seed=7)
    a, b = _mats()
    enc = np.asarray(sch.encode(a))
    results = np.einsum("nij,jk->nik", enc, b)
    mask = np.ones(24, np.float32)
    _, excluded, _ = screen_responders(sch, results, mask, max_exclude=20)
    assert excluded == []


# -------------------------------------------------- retries / degradation

def test_defended_round_retries_and_records_stats():
    a, b = _mats()
    ref = a @ b
    spec = _spec(fault=FaultSpec(crash_rate=0.12, corrupt_rate=0.12,
                                 corrupt_scale=1e3, handle=True,
                                 quarantine_after=2))
    total_retries = total_excluded = 0
    with Session(spec) as s:
        for _ in range(6):
            out, st = s.matmul(a, b)
            rel = np.linalg.norm(out - ref) / np.linalg.norm(ref)
            assert rel < 1e-2
            assert len(st.decode_mask) == spec.code.n_workers
            assert sum(st.decode_mask) == st.n_waited
            total_retries += st.retries
            total_excluded += len(st.excluded)
        assert s.health is not None
        snap = s.health.snapshot()
    assert total_retries >= 1
    assert total_excluded >= 1
    assert sum(snap["n_corrupt"]) >= 1


def test_rateless_degraded_round_reports_achieved_err():
    a, b = _mats()
    spec = _spec(
        straggler=StragglerSpec(n_stragglers=0),
        fault=FaultSpec(crash_rate=0.5, handle=True, max_retries=0,
                        seed=13))
    with Session(spec) as s:
        out, st = s.matmul(a, b)
    assert st.degraded
    assert st.achieved_rel_err is not None and st.achieved_rel_err >= 0
    assert out.shape == (a.shape[0], b.shape[1])


def test_threshold_scheme_raises_structured_degraded_error():
    a, b = _mats(m=32, d=16, n_out=8)
    spec = ClusterSpec(
        code=CodeSpec(scheme="mds", n_workers=8, k_blocks=4),
        straggler=StragglerSpec(n_stragglers=0), seed=2,
        fault=FaultSpec(crash_rate=0.9, handle=True, max_retries=1,
                        seed=21))
    with Session(spec) as s:
        with pytest.raises(DegradedRoundError) as ei:
            for _ in range(6):   # some round draws > n-k crashes
                s.matmul(a, b)
    err = ei.value
    assert err.needed >= 4
    assert len(err.clean_slots) < 4
    assert err.retries >= 0 and isinstance(err.excluded, tuple)


# ------------------------------------------------------------ WorkerHealth

def test_worker_health_quarantine_and_probation():
    h = WorkerHealth(4, quarantine_after=2, quarantine_rounds=3,
                     probation_ok=2)
    h.record_corrupt(1, 0)
    assert not h.is_quarantined(1, 1)
    h.record_corrupt(1, 1)          # second strike -> quarantined
    assert h.is_quarantined(1, 2)
    assert not h.is_quarantined(1, 5)   # 3 rounds served
    # offense during probation -> re-quarantined, doubled
    h.record_crash(1, 5)
    assert h.is_quarantined(1, 6)
    assert h.is_quarantined(1, 5 + 5)   # 2x quarantine_rounds
    # a clean streak through probation clears the slate
    h.record_ok(2, 0.01)
    assert 2 in h.ranked(1)
    assert 1 not in h.ranked(6)
    assert 1 not in h.ranked(6, exclude={1})


def test_worker_health_ranked_prefers_fast_workers():
    h = WorkerHealth(3)
    h.record_ok(0, 0.5)
    h.record_ok(1, 0.01)
    h.record_ok(2, 0.1)
    assert h.ranked(1) == [1, 2, 0]


# ------------------------------------------- transport satellites (a + b)

def test_stray_failure_tagged_with_originating_round():
    tr = ThreadTransport(2, StragglerModel(n_workers=2, n_stragglers=0,
                                           seed=0, delay_s=0.0))
    try:
        def f(x):
            if x == 1:
                time.sleep(0.15)
                raise RuntimeError("boom")
            return x

        h = tr.submit_round([0, 1], f, round_idx=5, t_compute=1e-4)
        it = h.events()
        ev = next(it)           # consume the healthy worker only
        assert ev.worker == 0
        h.finish()              # straggler still running: no error yet
        time.sleep(0.4)         # let the failure land
        with pytest.raises(RuntimeError,
                           match=r"originating round 5") as ei:
            h.finish()
        assert "boom" in str(ei.value.__cause__)
    finally:
        tr.close()


def test_stray_failure_still_surfaces_on_next_submit():
    tr = ThreadTransport(2, StragglerModel(n_workers=2, n_stragglers=0,
                                           seed=0, delay_s=0.0))
    try:
        def f(x):
            if x == 1:
                time.sleep(0.15)
                raise RuntimeError("boom")
            return x

        h = tr.submit_round([0, 1], f, round_idx=3, t_compute=1e-4)
        next(h.events())
        h.finish()
        time.sleep(0.4)
        with pytest.raises(RuntimeError, match=r"originating round 3"):
            tr.submit_round([0, 1], lambda x: x, 4, t_compute=1e-4)
    finally:
        tr.close()


def test_close_does_not_deadlock_on_blocked_worker():
    """Regression (satellite): Session/transport close used to join the
    executor unbounded — a crashed/never-arriving worker thread would
    hang shutdown forever."""
    tr = ThreadTransport(2, StragglerModel(n_workers=2, n_stragglers=0,
                                           seed=0, delay_s=0.0))
    tr.join_timeout_s = 0.3
    release = threading.Event()

    def f(x):
        if x == 1:
            release.wait()      # blocked until the test releases it
        return x

    h = tr.submit_round([0, 1], f, round_idx=0, t_compute=1e-4)
    next(h.events())
    h.finish()
    t0 = time.perf_counter()
    tr.close()
    elapsed = time.perf_counter() - t0
    release.set()               # let the abandoned thread exit cleanly
    assert elapsed < 1.5, f"close() blocked {elapsed:.2f}s on a stuck worker"


def test_session_close_bounded_with_inflight_threads_round():
    a, b = _mats(m=16, d=8, n_out=4)
    spec = _spec(
        code=CodeSpec(scheme="spacdc", n_workers=6, k_blocks=2,
                      fused=False, extra={"fh_degree": 3}),
        straggler=StragglerSpec(n_stragglers=2, delay_s=0.05),
        transport=TransportSpec(backend="threads"))
    s = Session(spec)
    s.matmul(a, b)              # leaves stragglers sleeping on the pool
    t0 = time.perf_counter()
    s.close()
    assert time.perf_counter() - t0 < 5.0
