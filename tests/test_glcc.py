"""Group Lagrange Coded Computing (arXiv 2204.11168): the grouped-LCC
scheme whose n_groups knob trades per-worker computation/communication
against recovery threshold.  g=1 must be bit-identical to LCC.
"""

import numpy as np
import pytest

from repro.core import registry
from repro.core.baselines import GLCCScheme, LCCScheme


def _x(seed=0, rows=24, d=8):
    rng = np.random.default_rng(seed)
    return rng.standard_normal((rows, d)).astype(np.float32)


def test_glcc_degenerate_group_matches_lcc_bitwise():
    kw = dict(n_workers=12, k_blocks=4, t_colluding=1, deg_f=2,
              noise_scale=0.05, seed=3)
    lcc = LCCScheme(**kw)
    glcc = GLCCScheme(n_groups=1, **kw)
    assert glcc.recovery_threshold == lcc.recovery_threshold
    np.testing.assert_array_equal(glcc.encoder, lcc.encoder)
    x = _x()
    np.testing.assert_array_equal(np.asarray(glcc.encode(x)),
                                  np.asarray(lcc.encode(x)))
    shards = np.asarray(lcc.encode(x))
    results = shards @ shards.transpose(0, 2, 1)   # f(X) = X X^T, deg 2
    resp = list(range(lcc.recovery_threshold))
    np.testing.assert_array_equal(np.asarray(glcc.decode(results, resp)),
                                  np.asarray(lcc.decode(results, resp)))


def test_glcc_threshold_drops_and_shards_grow_with_groups():
    prev_thr, prev_rows = None, None
    for g in (1, 2, 4):
        s = GLCCScheme(n_workers=12, k_blocks=4, t_colluding=1, deg_f=2,
                       n_groups=g, noise_scale=0.05, seed=3)
        shards = np.asarray(s.encode(_x()))
        rows = shards.shape[1]
        if prev_thr is not None:
            assert s.recovery_threshold < prev_thr
            assert rows > prev_rows     # the g× communication price
        prev_thr, prev_rows = s.recovery_threshold, rows
        # per-worker shard stacks one coded block per group
        assert rows == g * (24 // 4)


def test_glcc_exactness_linear_f():
    """deg_f=1 with f(X) = X @ B is within Lagrange conditioning of exact:
    decode recovers the K data blocks' products from any threshold-sized
    responder set."""
    rng = np.random.default_rng(1)
    b = rng.standard_normal((8, 5)).astype(np.float32)
    for g in (1, 2, 4):
        s = GLCCScheme(n_workers=12, k_blocks=4, t_colluding=0, deg_f=1,
                       n_groups=g, seed=3)
        x = _x()
        shards = np.asarray(s.encode(x))
        resp = [11, 3, 7, 0, 5][: s.recovery_threshold]
        results = shards[resp] @ b     # results aligned with the responders
        out = np.asarray(s.decode(results, resp))
        want = x.reshape(4, 6, 8) @ b
        err = np.linalg.norm(out - want) / np.linalg.norm(want)
        assert err < 1e-2, f"g={g}: rel err {err:.2e}"


def test_glcc_validation():
    with pytest.raises(ValueError, match="dividing"):
        GLCCScheme(n_workers=12, k_blocks=4, n_groups=3)
    with pytest.raises(ValueError, match="dividing"):
        GLCCScheme(n_workers=12, k_blocks=4, n_groups=0)
    with pytest.raises(ValueError, match="N >="):
        GLCCScheme(n_workers=4, k_blocks=6, n_groups=1, deg_f=2)
    # decoding below threshold refuses
    s = GLCCScheme(n_workers=12, k_blocks=4, n_groups=2, deg_f=2)
    with pytest.raises(ValueError):
        s.decode(np.zeros((2, 12, 8)), [0, 1])


def test_glcc_registry_build():
    s = registry.build("glcc", n_workers=12, k_blocks=6, t_colluding=1,
                       deg_f=2, n_groups=3, noise_scale=0.05, seed=0)
    assert isinstance(s, GLCCScheme)
    assert s.n_groups == 3 and s.per_group == 2
    # registry.build drops kwargs the factory doesn't take (use_kernel)
    s2 = registry.build("glcc", n_workers=12, k_blocks=6, use_kernel=None)
    assert s2.n_groups == 1
