"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (interpret
mode executes the Pallas kernel body in Python on CPU)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ref
from repro.kernels.berrut_encode import berrut_encode_kernel
from repro.kernels.flash_attention import flash_attention_kernel
from repro.kernels.mask_add import mask_add_kernel

rng = np.random.default_rng(0)


def _rand_limbs(n, q, n_limbs, seed):
    """(n, n_limbs) uint32 planes of uniform field elements < q."""
    from repro.crypto.field import int_to_limbs
    r = np.random.default_rng(seed)
    vals = [int.from_bytes(r.bytes((q.bit_length() + 7) // 8), "big") % q
            for _ in range(n)]
    return np.stack([int_to_limbs(v, n_limbs) for v in vals]), vals


from repro.crypto import CURVE_SECP256K1

SECP_Q = CURVE_SECP256K1.q


@pytest.mark.parametrize("n", [1, 100, 513, 4096])
@pytest.mark.parametrize("subtract", [False, True])
def test_mask_add_kernel_matches_oracle(n, subtract):
    from repro.crypto.field import int_to_limbs
    a, av = _rand_limbs(n, SECP_Q, 8, seed=n)
    b, bv = _rand_limbs(n, SECP_Q, 8, seed=n + 1)
    q_limbs = tuple(int(v) for v in int_to_limbs(SECP_Q, 8))
    out = mask_add_kernel(jnp.asarray(a), jnp.asarray(b), q_limbs=q_limbs,
                          subtract=subtract, interpret=True)
    want = ref.mask_add(a, b, np.asarray(q_limbs, np.uint32),
                        subtract=subtract)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))
    # and both match big-int ground truth
    from repro.crypto.field import limbs_to_int
    got = limbs_to_int(np.asarray(out))
    for g, x, y in zip(got, av, bv):
        assert int(g) == ((x - y) if subtract else (x + y)) % SECP_Q


def test_mask_add_kernel_edge_values():
    """Carry/borrow chains at the field edges: 0, 1, q-1, 2^256-adjacent."""
    from repro.kernels.ops import mask_add
    from repro.crypto.field import int_to_limbs, limbs_to_int
    vals = [0, 1, 2, SECP_Q - 1, SECP_Q - 2, (1 << 255) % SECP_Q,
            0xFFFFFFFF, 0xFFFFFFFF00000000 % SECP_Q]
    a = np.stack([int_to_limbs(v, 8) for v in vals])
    for other in (0, 1, SECP_Q - 1):
        b = np.broadcast_to(int_to_limbs(other, 8), a.shape)
        for subtract in (False, True):
            for force in (False, True):
                out = mask_add(a, b, SECP_Q, subtract=subtract,
                               force_kernel=force)
                got = limbs_to_int(np.asarray(out))
                for g, x in zip(got, vals):
                    want = (x - other) if subtract else (x + other)
                    assert int(g) == want % SECP_Q, (x, other, subtract)


def test_mask_add_broadcast_scalar_mask():
    """Paper mode masks every element with one field scalar."""
    from repro.kernels.ops import mask_add
    from repro.crypto.field import int_to_limbs, limbs_to_int
    a, av = _rand_limbs(37, SECP_Q, 8, seed=3)
    psi = 0x123456789ABCDEF0FEDCBA9876543210
    out = mask_add(a, int_to_limbs(psi, 8), SECP_Q, force_kernel=True)
    for g, x in zip(limbs_to_int(np.asarray(out)), av):
        assert int(g) == (x + psi) % SECP_Q


@pytest.mark.parametrize("q,j,m", [(8, 6, 1000), (20, 8, 4096), (3, 3, 77),
                                   (64, 32, 2048), (1, 1, 129)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_berrut_kernel_matches_oracle(q, j, m, dtype):
    w = jnp.asarray(rng.standard_normal((q, j)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((j, m)), dtype)
    out = berrut_encode_kernel(w, b, interpret=True)
    want = ref.berrut_combine(w, b)
    tol = 1e-4 if dtype == jnp.float32 else 0.15
    assert out.shape == want.shape and out.dtype == want.dtype
    assert float(jnp.max(jnp.abs(out.astype(jnp.float32) -
                                 want.astype(jnp.float32)))) < tol


@pytest.mark.parametrize("b,sq,skv,h,kv,hd,causal", [
    (1, 128, 128, 4, 4, 64, True),
    (2, 100, 100, 4, 2, 32, True),
    (1, 256, 256, 8, 8, 128, False),
    (2, 64, 192, 4, 1, 64, False),
    (1, 65, 130, 2, 2, 48, True),        # ragged, padded tiles
])
def test_flash_kernel_matches_oracle(b, sq, skv, h, kv, hd, causal):
    q = jnp.asarray(rng.standard_normal((b, sq, h, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, skv, kv, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, skv, kv, hd)), jnp.float32)
    out = flash_attention_kernel(q, k, v, causal=causal, bq=64, bkv=64,
                                 interpret=True)
    want = ref.mha_reference(q, k, v, causal=causal)
    assert float(jnp.max(jnp.abs(out - want))) < 3e-5


def test_flash_kernel_softcap():
    q = jnp.asarray(rng.standard_normal((1, 64, 2, 2, 32))[:, :, 0], jnp.float32)
    q = jnp.asarray(rng.standard_normal((1, 64, 2, 32)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 64, 2, 32)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 64, 2, 32)), jnp.float32)
    out = flash_attention_kernel(q, k, v, causal=True, softcap=20.0,
                                 bq=64, bkv=64, interpret=True)
    want = ref.mha_reference(q, k, v, causal=True, softcap=20.0)
    assert float(jnp.max(jnp.abs(out - want))) < 3e-5


def test_xla_flash_vjp_matches_dense_grads():
    """The train-path custom-vjp flash backward vs autodiff through the
    dense reference."""
    from repro.models.attention import flash_attention as xla_flash
    b, s, h, kv, hd = 2, 48, 4, 2, 16
    q = jnp.asarray(rng.standard_normal((b, s, h, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, kv, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, kv, hd)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    def via_flash(q, k, v):
        return jnp.sum(jnp.sin(xla_flash(q, k, v, q_positions=pos,
                                         kv_positions=pos, causal=True,
                                         chunk=16)))

    def via_ref(q, k, v):
        return jnp.sum(jnp.sin(ref.mha_reference(q, k, v, causal=True)))

    g1 = jax.grad(via_flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(via_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(g1, g2):
        assert float(jnp.max(jnp.abs(a - b_))) < 1e-5
