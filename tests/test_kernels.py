"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (interpret
mode executes the Pallas kernel body in Python on CPU)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ref
from repro.kernels.berrut_encode import berrut_encode_kernel
from repro.kernels.flash_attention import flash_attention_kernel

rng = np.random.default_rng(0)


@pytest.mark.parametrize("q,j,m", [(8, 6, 1000), (20, 8, 4096), (3, 3, 77),
                                   (64, 32, 2048), (1, 1, 129)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_berrut_kernel_matches_oracle(q, j, m, dtype):
    w = jnp.asarray(rng.standard_normal((q, j)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((j, m)), dtype)
    out = berrut_encode_kernel(w, b, interpret=True)
    want = ref.berrut_combine(w, b)
    tol = 1e-4 if dtype == jnp.float32 else 0.15
    assert out.shape == want.shape and out.dtype == want.dtype
    assert float(jnp.max(jnp.abs(out.astype(jnp.float32) -
                                 want.astype(jnp.float32)))) < tol


@pytest.mark.parametrize("b,sq,skv,h,kv,hd,causal", [
    (1, 128, 128, 4, 4, 64, True),
    (2, 100, 100, 4, 2, 32, True),
    (1, 256, 256, 8, 8, 128, False),
    (2, 64, 192, 4, 1, 64, False),
    (1, 65, 130, 2, 2, 48, True),        # ragged, padded tiles
])
def test_flash_kernel_matches_oracle(b, sq, skv, h, kv, hd, causal):
    q = jnp.asarray(rng.standard_normal((b, sq, h, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, skv, kv, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, skv, kv, hd)), jnp.float32)
    out = flash_attention_kernel(q, k, v, causal=causal, bq=64, bkv=64,
                                 interpret=True)
    want = ref.mha_reference(q, k, v, causal=causal)
    assert float(jnp.max(jnp.abs(out - want))) < 3e-5


def test_flash_kernel_softcap():
    q = jnp.asarray(rng.standard_normal((1, 64, 2, 2, 32))[:, :, 0], jnp.float32)
    q = jnp.asarray(rng.standard_normal((1, 64, 2, 32)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 64, 2, 32)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 64, 2, 32)), jnp.float32)
    out = flash_attention_kernel(q, k, v, causal=True, softcap=20.0,
                                 bq=64, bkv=64, interpret=True)
    want = ref.mha_reference(q, k, v, causal=True, softcap=20.0)
    assert float(jnp.max(jnp.abs(out - want))) < 3e-5


def test_xla_flash_vjp_matches_dense_grads():
    """The train-path custom-vjp flash backward vs autodiff through the
    dense reference."""
    from repro.models.attention import flash_attention as xla_flash
    b, s, h, kv, hd = 2, 48, 4, 2, 16
    q = jnp.asarray(rng.standard_normal((b, s, h, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, kv, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, kv, hd)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    def via_flash(q, k, v):
        return jnp.sum(jnp.sin(xla_flash(q, k, v, q_positions=pos,
                                         kv_positions=pos, causal=True,
                                         chunk=16)))

    def via_ref(q, k, v):
        return jnp.sum(jnp.sin(ref.mha_reference(q, k, v, causal=True)))

    g1 = jax.grad(via_flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(via_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(g1, g2):
        assert float(jnp.max(jnp.abs(a - b_))) < 1e-5
