"""Per-architecture smoke tests: reduced same-family config, one forward /
train step + one decode step on CPU; output shapes + no NaNs; param/spec
treedef agreement (the sharding contract)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, tiny_config
from repro.data.pipeline import make_batch
from repro.models import build_model

KEY = jax.random.PRNGKey(0)


def _tiny_batch(cfg, b=2, s=32):
    rng = np.random.default_rng(0)
    if cfg.encoder_decoder:
        return {"frames": jnp.asarray(rng.standard_normal((b, s, cfg.d_model)),
                                      jnp.bfloat16),
                "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, 8)),
                                      jnp.int32),
                "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, 8)),
                                       jnp.int32)}
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)),
                                   jnp.int32),
             "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)),
                                    jnp.int32)}
    if cfg.mrope_sections:
        batch["mrope_positions"] = jnp.asarray(
            np.broadcast_to(np.arange(s), (3, b, s)).copy(), jnp.int32)
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_loss_and_grads(arch):
    cfg = tiny_config(arch)
    model = build_model(cfg)
    params = model.init(KEY)
    batch = _tiny_batch(cfg)
    (loss, metrics), grads = jax.jit(
        jax.value_and_grad(model.loss_fn, has_aux=True))(params, batch)
    assert jnp.isfinite(loss), arch
    gnorm = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32))))
                for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, arch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_decode_step(arch):
    cfg = tiny_config(arch)
    model = build_model(cfg)
    params = model.init(KEY)
    b = 2
    cache = model.init_cache(b, 64)
    tok = jnp.zeros((b, 1), jnp.int32)
    kw = {}
    if cfg.mrope_sections:
        kw["mrope_positions"] = jnp.zeros((3, b, 1), jnp.int32)
    if cfg.encoder_decoder:
        step = jax.jit(lambda p, c, t: model.decode_step(p, c, t, 1))
    else:
        step = jax.jit(lambda p, c, t: model.decode_step(p, c, t, 1, **kw))
    logits, new_cache = step(params, cache, tok)
    assert logits.shape == (b, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert jax.tree.structure(new_cache) == jax.tree.structure(cache)


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_param_specs_match_structure(arch):
    cfg = tiny_config(arch)
    model = build_model(cfg)
    shapes = jax.eval_shape(model.init, KEY)
    specs = model.param_specs()
    flat_sh = jax.tree.leaves(shapes)
    flat_sp = jax.tree.flatten(specs, is_leaf=lambda s: isinstance(s, P))[0]
    assert len(flat_sh) == len(flat_sp), arch
    for sd, sp in zip(flat_sh, flat_sp):
        assert len(sp) <= len(sd.shape), (arch, sd.shape, sp)


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_cache_specs_match_structure(arch):
    cfg = tiny_config(arch)
    model = build_model(cfg)
    shapes = jax.eval_shape(lambda: model.init_cache(2, 32))
    specs = model.cache_specs()
    flat_sh = jax.tree.leaves(shapes)
    flat_sp = jax.tree.flatten(specs, is_leaf=lambda s: isinstance(s, P))[0]
    assert len(flat_sh) == len(flat_sp), arch


@pytest.mark.parametrize("arch", ["qwen2-7b", "deepseek-v2-lite-16b",
                                  "command-r-35b", "jamba-v0.1-52b"])
def test_decode_matches_forward_causal(arch):
    """Teacher-forced forward logits at position t == incremental decode
    logits — exercises the GQA cache, the MLA absorbed-decode algebra, the
    parallel-block residual and the hybrid mamba/attn caches.

    MoE archs: capacity_factor raised so no token drops — the train path
    drops over-capacity tokens while all-expert decode never does (an
    intended train/serve semantic difference, not a bug)."""
    import dataclasses
    # f32 compute: in bf16, router top-k near-ties flip experts between the
    # parallel and incremental paths (discontinuous but correct behaviour —
    # measured as a single-token logit jump); f32 isolates the cache algebra
    cfg = dataclasses.replace(tiny_config(arch), compute_dtype="float32")
    tol = dict(atol=0.05, rtol=0.05)
    if cfg.moe:
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    model = build_model(cfg)
    params = model.init(KEY)
    rng = np.random.default_rng(3)
    b, s = 1, 8
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    full_logits, _ = model.forward(params, toks)
    cache = model.init_cache(b, 16)
    outs = []
    for t in range(s):
        logits, cache = model.decode_step(params, cache, toks[:, t:t + 1], t)
        outs.append(logits[:, 0])
    inc = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(inc, np.float32),
                               np.asarray(full_logits, np.float32), **tol)


def test_rwkv_decode_matches_forward():
    cfg = tiny_config("rwkv6-1.6b")
    model = build_model(cfg)
    params = model.init(KEY)
    rng = np.random.default_rng(4)
    b, s = 1, 6
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    full_logits, _ = model.forward(params, toks)
    cache = model.init_cache(b, 16)
    outs = []
    for t in range(s):
        logits, cache = model.decode_step(params, cache, toks[:, t:t + 1], t)
        outs.append(logits[:, 0])
    inc = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(inc, np.float32),
                               np.asarray(full_logits, np.float32),
                               atol=0.2, rtol=0.1)


def test_make_batch_matches_input_specs():
    from repro.configs.base import ShapeSpec
    from repro.models import input_specs
    cfg = tiny_config("qwen2-vl-72b")
    spec = ShapeSpec("t", 64, 4, "train")
    batch = make_batch(cfg, spec)
    structs = input_specs(cfg, spec)
    assert set(batch) == set(structs)
    for k in batch:
        assert tuple(batch[k].shape) == tuple(structs[k].shape), k
