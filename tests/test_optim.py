import numpy as np
import jax
import jax.numpy as jnp

from repro.optim import adamw, sgdm, clip_by_global_norm, warmup_cosine
from repro.optim.optimizers import apply_updates


def _train_quadratic(opt, steps=120):
    params = {"w": jnp.asarray([3.0, -2.0]), "b": jnp.asarray(1.5)}

    def loss(p):
        return jnp.sum(p["w"] ** 2) + p["b"] ** 2

    state = opt.init(params)
    for _ in range(steps):
        g = jax.grad(loss)(params)
        upd, state = opt.update(g, state, params)
        params = apply_updates(params, upd)
    return float(loss(params))


def test_adamw_converges():
    assert _train_quadratic(adamw(0.1, weight_decay=0.0)) < 5e-2


def test_sgdm_converges():
    assert _train_quadratic(sgdm(0.05)) < 5e-2


def test_clipping():
    tree = {"a": jnp.asarray([3.0, 4.0])}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert abs(float(norm) - 5.0) < 1e-5
    np.testing.assert_allclose(np.asarray(clipped["a"]), [0.6, 0.8], rtol=1e-5)


def test_warmup_cosine_shape():
    sched = warmup_cosine(1.0, 10, 100)
    v5 = float(sched(jnp.asarray(5)))
    v10 = float(sched(jnp.asarray(10)))
    v100 = float(sched(jnp.asarray(100)))
    assert 0 < v5 < v10 <= 1.0
    assert v100 < v10 and abs(v100 - 0.1) < 1e-2


def test_weight_decay_pulls_to_zero():
    opt = adamw(0.05, weight_decay=1.0, max_grad_norm=0.0)
    params = {"w": jnp.asarray(5.0)}
    state = opt.init(params)
    for _ in range(50):
        upd, state = opt.update({"w": jnp.asarray(0.0)}, state, params)
        params = apply_updates(params, upd)
    assert abs(float(params["w"])) < 1.0
