import numpy as np
import jax
import jax.numpy as jnp

from repro.core import SPACDCCode, SPACDCConfig
from repro.core.privacy import (empirical_leakage, gaussian_mi_bound,
                                min_noise_scale_for)


def test_mi_bound_decreases_with_noise():
    prev = None
    for scale in (0.5, 2.0, 8.0):
        code = SPACDCCode(SPACDCConfig(10, 3, t_colluding=2, noise_scale=scale))
        b = gaussian_mi_bound(code).max()
        if prev is not None:
            assert b < prev
        prev = b


def test_no_noise_means_no_privacy():
    code = SPACDCCode(SPACDCConfig(10, 3, t_colluding=0))
    assert np.isinf(gaussian_mi_bound(code)).all()


def test_min_noise_scale_achieves_target():
    cfg = SPACDCConfig(12, 4, t_colluding=2, noise_scale=1.0)
    code = SPACDCCode(cfg)
    target_bits = 0.01
    scale = min_noise_scale_for(code, target_bits)
    code2 = SPACDCCode(SPACDCConfig(12, 4, 2, noise_scale=scale))
    assert gaussian_mi_bound(code2).max() <= target_bits * 1.01


def test_empirical_leakage_shrinks():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((24, 8)), jnp.float32)
    weak = SPACDCCode(SPACDCConfig(8, 2, 1, noise_scale=0.3))
    strong = SPACDCCode(SPACDCConfig(8, 2, 1, noise_scale=30.0))
    lw = empirical_leakage(weak, x, jax.random.PRNGKey(0), n_trials=48)
    ls = empirical_leakage(strong, x, jax.random.PRNGKey(0), n_trials=48)
    assert ls < lw
