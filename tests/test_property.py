"""Hypothesis property tests on the system's invariants."""

import numpy as np
import jax
import jax.numpy as jnp
try:
    from hypothesis import given, settings, strategies as st
except ImportError:    # container without hypothesis: deterministic fallback
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import SPACDCCode, SPACDCConfig, berrut, pad_to_blocks
from repro.crypto.mea_ecc import FixedPointCodec
from repro.crypto import CURVE_SECP256K1
from repro.dist.compression import int8_compress, int8_decompress

SET = dict(max_examples=25, deadline=None)


@settings(**SET)
@given(n=st.integers(3, 24), seed=st.integers(0, 2**16))
def test_berrut_weights_always_sum_to_one(n, seed):
    rng = np.random.default_rng(seed)
    nodes = np.sort(rng.uniform(-1, 1, n))
    if len(np.unique(nodes)) < n:
        return
    x = rng.uniform(-2, 2, 5)
    w = berrut.berrut_weights(jnp.asarray(x), jnp.asarray(nodes))
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, atol=1e-4)


@settings(**SET)
@given(q=st.integers(1, 12), j=st.integers(1, 8), seed=st.integers(0, 99))
def test_combine_is_linear(q, j, seed):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.standard_normal((q, j)), jnp.float32)
    a = jnp.asarray(rng.standard_normal((j, 3)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((j, 3)), jnp.float32)
    lhs = berrut.combine(w, a + b)
    rhs = berrut.combine(w, a) + berrut.combine(w, b)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), atol=1e-4)


@settings(**SET)
@given(m=st.integers(1, 40), k=st.integers(1, 8))
def test_pad_to_blocks_divisible(m, k):
    x = jnp.ones((m, 2))
    out = pad_to_blocks(x, k)
    assert out.shape[0] % k == 0
    assert float(out.sum()) == 2 * m          # padding is zeros
    assert out.shape[0] - m < k


@settings(**SET)
@given(seed=st.integers(0, 99),
       vals=st.lists(st.floats(-1e4, 1e4, allow_nan=False), min_size=1,
                     max_size=20))
def test_fixed_point_codec_roundtrip(seed, vals):
    codec = FixedPointCodec(CURVE_SECP256K1.q, frac_bits=16)
    m = np.asarray(vals, np.float32).reshape(-1, 1)
    out = codec.decode(codec.encode(m))
    np.testing.assert_allclose(out, np.round(m * 2**16) / 2**16, atol=1e-9)


@settings(**SET)
@given(seed=st.integers(0, 99), scale=st.floats(0.01, 100))
def test_int8_compression_error_bound(seed, scale):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(64) * scale, jnp.float32)
    q, s = int8_compress(x)
    deq = int8_decompress(q, s)
    max_err = float(jnp.max(jnp.abs(deq - x)))
    assert max_err <= float(s) * 0.5 + 1e-6   # round-to-nearest bound


@settings(**SET)
@given(n=st.integers(4, 16), k=st.integers(1, 4), seed=st.integers(0, 50))
def test_decode_weights_renormalize_over_any_mask(n, k, seed):
    if k > n:
        return
    code = SPACDCCode(SPACDCConfig(n, k))
    rng = np.random.default_rng(seed)
    mask = np.zeros(n, np.float32)
    mask[rng.choice(n, size=rng.integers(1, n + 1), replace=False)] = 1.0
    dm_rows = code.decode_masked(jnp.eye(n, dtype=jnp.float32),
                                 jnp.asarray(mask))
    # decode of identity basis: rows are the decode weights; they sum to 1
    np.testing.assert_allclose(np.asarray(dm_rows.sum(-1)), 1.0, atol=1e-3)
    # non-responders get zero weight
    dead = np.where(mask == 0)[0]
    assert np.abs(np.asarray(dm_rows)[:, dead]).max() < 1e-6 if len(dead) else True


@settings(**SET)
@given(seed=st.integers(0, 30))
def test_gradient_code_decoder_weights_sum_to_one(seed):
    from repro.core import BerrutGradientCode
    rng = np.random.default_rng(seed)
    g = BerrutGradientCode(n_shards=8, n_blocks=8)
    mask = np.zeros(8, np.float32)
    mask[rng.choice(8, size=rng.integers(1, 9), replace=False)] = 1.0
    w = g.decoder_weights(jnp.asarray(mask)) * mask
    np.testing.assert_allclose(float(jnp.sum(w)), 1.0, atol=1e-3)
