"""CodingScheme registry: construction, scheme parity (every registered
scheme round-trips decode(f(encode(X))) against the uncoded oracle, on both
the jnp path and the Pallas-kernel interpret path, float32 + bfloat16), the
SPACDC use_kernel flag, and the runtime's registry-driven construction."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import SPACDCCode, SPACDCConfig, registry

rng = np.random.default_rng(0)
M, D, NOUT = 24, 12, 8
A_NP = rng.standard_normal((M, D))
B_NP = rng.standard_normal((D, NOUT))

SCHEME_CFGS = {
    "conv": dict(n_workers=6),
    "mds": dict(n_workers=12, k_blocks=4),
    "lcc": dict(n_workers=12, k_blocks=4, deg_f=1),   # deg 1: f is linear
    "bacc": dict(n_workers=12, k_blocks=4),
    "spacdc": dict(n_workers=12, k_blocks=4, t_colluding=1),
    "matdot": dict(n_workers=12, k_blocks=4),
    "polynomial": dict(n_workers=12, p=2, q=2),
    "secpoly": dict(n_workers=12, p=2, q=2),
}

# max relative error of the full-responder round trip.  Berrut-family
# schemes are approximate by design (rateless interpolation); the others
# are exact up to float noise.  bfloat16: the real-Vandermonde threshold
# codes amplify the shards' bf16 quantization by cond(V) — decode parity is
# only meaningful in f32 for them (None = finite/shape smoke only), which
# matches how the paper runs them.
TOL_F32 = {"spacdc": 0.30, "bacc": 0.15}
TOL_BF16 = {"spacdc": 0.35, "bacc": 0.20, "conv": 0.02,
            "mds": None, "lcc": None, "matdot": None, "polynomial": None,
            "secpoly": None}
DEFAULT_TOL_F32 = 5e-3


def _roundtrip(scheme, dtype):
    a = jnp.asarray(A_NP, dtype)
    b = jnp.asarray(B_NP, dtype)
    if scheme.pair_coded:
        ea, eb = scheme.encode_pair(a, b)
        results = jnp.einsum("nij,njk->nik", ea, eb)
    else:
        shards = scheme.encode(a)
        results = jax.vmap(lambda s: s @ b)(shards)
    wait = scheme.wait_policy(0)
    decoded = scheme.decode(results[:wait], list(range(wait)))
    return np.asarray(scheme.reconstruct_matmul(decoded, M, NOUT), np.float32)


@pytest.mark.parametrize("use_kernel", [False, True],
                         ids=["jnp", "kernel-interpret"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16],
                         ids=["f32", "bf16"])
@pytest.mark.parametrize("name", sorted(SCHEME_CFGS))
def test_scheme_roundtrip_parity(name, dtype, use_kernel):
    scheme = registry.build(name, use_kernel=use_kernel, **SCHEME_CFGS[name])
    out = _roundtrip(scheme, dtype)
    oracle = A_NP.astype(np.float32) @ B_NP.astype(np.float32)
    assert out.shape == oracle.shape
    assert np.all(np.isfinite(out))
    tol = (TOL_F32.get(name, DEFAULT_TOL_F32) if dtype == jnp.float32
           else TOL_BF16.get(name, 0.05))
    if tol is not None:
        rel = np.abs(out - oracle).max() / np.abs(oracle).max()
        assert rel < tol, (name, dtype, use_kernel, rel)


@pytest.mark.parametrize("name", sorted(SCHEME_CFGS))
def test_kernel_path_matches_jnp_path(name):
    """The Pallas interpret kernel and the XLA twin are bit-comparable."""
    jnp_out = _roundtrip(registry.build(name, use_kernel=False,
                                        **SCHEME_CFGS[name]), jnp.float32)
    ker_out = _roundtrip(registry.build(name, use_kernel=True,
                                        **SCHEME_CFGS[name]), jnp.float32)
    np.testing.assert_allclose(ker_out, jnp_out, atol=2e-4, rtol=2e-4)


def test_registry_unknown_scheme_lists_available():
    with pytest.raises(KeyError, match="spacdc"):
        registry.build("nope", n_workers=4)


def test_runtime_kwargs_flow_to_scheme():
    from repro.runtime.master_worker import DistributedMatmul
    dist = DistributedMatmul("spacdc", 8, 4, noise_scale=0.5)
    assert dist.scheme.cfg.noise_scale == 0.5


def test_polynomial_honors_k_blocks():
    """The shared runtime config's block count maps to a p=k, q=1 split."""
    s = registry.build("polynomial", n_workers=12, k_blocks=6)
    assert (s.p, s.q, s.recovery_threshold) == (6, 1, 6)


def test_matdot_requires_block_count():
    with pytest.raises(ValueError, match="k_blocks"):
        registry.build("matdot", n_workers=12)


def test_registry_drops_unknown_kwargs():
    s = registry.build("conv", n_workers=4, k_blocks=2, t_colluding=1,
                       noise_scale=1.0, seed=0)
    assert s.n_workers == 4 and s.recovery_threshold == 4


def test_duplicate_registration_rejected():
    with pytest.raises(ValueError):
        registry.register("spacdc", lambda n_workers: None)


def test_wait_policy_rateless_vs_threshold():
    spa = registry.build("spacdc", n_workers=10, k_blocks=4)
    mds = registry.build("mds", n_workers=10, k_blocks=4)
    assert spa.wait_policy(3) == 7          # rateless: everyone not straggling
    assert mds.wait_policy(3) == 4          # threshold: K regardless


def test_default_decode_masked_matches_decode():
    mds = registry.build("mds", n_workers=8, k_blocks=3)
    shards = mds.encode(jnp.asarray(A_NP, jnp.float32))
    res = jax.vmap(lambda s: s @ jnp.asarray(B_NP, jnp.float32))(shards)
    mask = np.zeros(8, np.float32)
    resp = np.asarray([1, 4, 6])
    mask[resp] = 1.0
    d1 = mds.decode(res[resp], resp)
    d2 = mds.decode_masked(res, mask)
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), atol=1e-5)


def test_spacdc_use_kernel_flag_on_config():
    """The documented SPACDCConfig(use_kernel=...) flag is real and the two
    paths agree (satellite of the registry refactor)."""
    x = jnp.asarray(A_NP, jnp.float32)
    ref_code = SPACDCCode(SPACDCConfig(10, 4, 1, use_kernel=False))
    ker_code = SPACDCCode(SPACDCConfig(10, 4, 1, use_kernel=True))
    assert ref_code.use_kernel is False and ker_code.use_kernel is True
    e1, e2 = ref_code.encode(x), ker_code.encode(x)
    np.testing.assert_allclose(np.asarray(e1), np.asarray(e2),
                               atol=1e-5, rtol=1e-5)
    resp = [0, 2, 3, 5, 7, 9]
    d1 = ref_code.decode(e1[np.asarray(resp)], resp)
    d2 = ker_code.decode(e2[np.asarray(resp)], resp)
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2),
                               atol=1e-5, rtol=1e-5)


def test_spacdc_use_kernel_constructor_override():
    code = SPACDCCode(SPACDCConfig(8, 2), use_kernel=True)
    assert code.use_kernel is True


def test_distributed_matmul_builds_any_registered_scheme():
    """Schemes the old if/elif runtime never supported now drop in."""
    from repro.runtime.master_worker import DistributedMatmul
    a = A_NP.astype(np.float32)
    b = B_NP.astype(np.float32)
    for name, kwargs in [("bacc", {}), ("polynomial", dict(p=2, q=2)),
                         ("lcc", dict(deg_f=1))]:
        dist = DistributedMatmul(name, n_workers=10, k_blocks=2,
                                 n_stragglers=1, **kwargs)
        out, stats = dist.matmul(a, b)
        rel = np.abs(out - a @ b).max() / np.abs(a @ b).max()
        assert rel < (0.25 if name == "bacc" else 1e-2), (name, rel)
        assert stats.total_s > 0
