"""Master/worker runtime + straggler model behaviour (the paper's §VII-B
experimental apparatus)."""

import numpy as np
import pytest

from repro.data.mnist import synthetic_mnist
from repro.runtime import StragglerModel
from repro.runtime.master_worker import CodedMaster, DistributedMatmul

rng = np.random.default_rng(0)
A = rng.standard_normal((256, 64)).astype(np.float32)
B = rng.standard_normal((64, 32)).astype(np.float32)


def test_straggler_model_deterministic():
    s = StragglerModel(10, 3, seed=1)
    np.testing.assert_array_equal(s.delays(5), s.delays(5))
    assert (s.delays(5) != s.delays(6)).any()


def test_straggler_count():
    s = StragglerModel(20, 5, delay_s=1.0)
    d = s.delays(0)
    assert (d > 0.5).sum() == 5


def test_straggler_paper_mode_unchanged_by_new_modes():
    """mode='paper' (the default) must reproduce the seed's exact rng
    stream — existing traces and Fig-3 sweeps stay bit-identical."""
    s = StragglerModel(12, 4, seed=7)
    rng = np.random.default_rng(np.random.SeedSequence([7, 3]))
    want = rng.exponential(s.jitter_scale, 12)
    idx = rng.choice(12, 4, replace=False)
    want[idx] += s.delay_s * (1.0 + rng.random(4))
    np.testing.assert_array_equal(s.delays(3), want)
    assert s.mode == "paper"


@pytest.mark.parametrize("mode", ["pareto", "markov"])
def test_straggler_new_modes_deterministic(mode):
    s = StragglerModel(10, 3, seed=1, mode=mode)
    np.testing.assert_array_equal(s.delays(5), s.delays(5))
    assert (s.delays(5) != s.delays(6)).any()
    assert (s.delays(5) >= 0).all()


def test_straggler_pareto_has_heavier_tail():
    paper = StragglerModel(200, 0, seed=0)
    pareto = StragglerModel(200, 0, seed=0, mode="pareto")
    d_paper = np.concatenate([paper.delays(r) for r in range(5)])
    d_pareto = np.concatenate([pareto.delays(r) for r in range(5)])
    # jitter-only paper delays never reach delay_s scale; the heavy tail does
    assert d_paper.max() < 0.02 < d_pareto.max()
    assert np.median(d_pareto) < 0.01          # ...while the bulk stays fast


def test_straggler_markov_bursts_persist_across_rounds():
    s = StragglerModel(20, 5, seed=3, mode="markov", p_fail=0.05,
                       p_recover=0.3)
    slow_sets = [set(np.flatnonzero(s.delays(r) > 0.5 * s.delay_s))
                 for r in range(6)]
    # congestion is correlated round-to-round (bursts), unlike paper mode
    overlaps = [len(a & b) for a, b in zip(slow_sets, slow_sets[1:])
                if a or b]
    assert overlaps and max(overlaps) >= 1
    with pytest.raises(ValueError):
        StragglerModel(4, 1, mode="quantum")


@pytest.mark.parametrize("scheme,kwargs", [
    ("conv", {}),
    ("mds", {}),
    ("matdot", {}),
    ("spacdc", {"t_colluding": 1}),
])
def test_distributed_matmul_accuracy(scheme, kwargs):
    dist = DistributedMatmul(scheme, n_workers=10, k_blocks=4,
                             n_stragglers=2, **kwargs)
    out, stats = dist.matmul(A, B)
    rel = np.abs(out - A @ B).max() / np.abs(A @ B).max()
    tol = 0.25 if scheme == "spacdc" else 1e-2
    assert rel < tol, (scheme, rel)
    assert stats.total_s > 0


def test_conv_waits_for_stragglers():
    """The uncoded baseline pays the straggler delay; coded schemes don't."""
    conv = DistributedMatmul("conv", 10, 4, n_stragglers=2, seed=3)
    mds = DistributedMatmul("mds", 10, 4, n_stragglers=2, seed=3)
    _, s_conv = conv.matmul(A, B, round_idx=1)
    _, s_mds = mds.matmul(A, B, round_idx=1)
    assert s_conv.compute_wait_s > s_mds.compute_wait_s


def test_spacdc_rateless_vs_threshold_collision():
    """Paper's key scenario: when stragglers push survivors below the MDS
    recovery threshold, MDS must wait for a straggler — SPACDC proceeds."""
    n, k, s = 12, 10, 4   # threshold 10 > 12-4=8 survivors
    mds = DistributedMatmul("mds", n, k, n_stragglers=s, seed=7)
    spa = DistributedMatmul("spacdc", n, k, t_colluding=1, n_stragglers=s, seed=7)
    _, st_mds = mds.matmul(A, B, round_idx=2)
    _, st_spa = spa.matmul(A, B, round_idx=2)
    assert st_spa.compute_wait_s < st_mds.compute_wait_s


def test_coded_master_trains():
    xtr, ytr, xte, yte = synthetic_mnist(n_train=1024, n_test=256)
    dist = DistributedMatmul("spacdc", n_workers=8, k_blocks=4,
                             t_colluding=1, n_stragglers=1)
    m = CodedMaster((784, 64, 10), dist, lr=0.1)
    for ep in range(2):
        for i in range(0, 1024, 256):
            loss, el = m.train_batch(xtr[i:i + 256], ytr[i:i + 256])
    assert m.accuracy(xte, yte) > 0.8


def test_coded_master_trains_under_error_target():
    """Training under ErrorTarget: every backward round decodes at the
    earliest prefix whose embedded error estimate meets the target."""
    from repro.runtime import ErrorTarget
    xtr, ytr, xte, yte = synthetic_mnist(n_train=512, n_test=128)
    dist = DistributedMatmul("spacdc", n_workers=8, k_blocks=4,
                             t_colluding=1, n_stragglers=1)
    m = CodedMaster((784, 32, 10), dist, lr=0.1,
                    wait_policy=ErrorTarget(0.25))
    for i in range(0, 512, 256):
        loss, _ = m.train_batch(xtr[i:i + 256], ytr[i:i + 256])
        assert np.isfinite(loss)
    assert all(s.policy == "error_target" for s in m.round_stats)
    assert all(1 <= s.n_waited <= 8 for s in m.round_stats)


def test_crypto_overhead_accounted():
    dist = DistributedMatmul("spacdc", 6, 3, t_colluding=1, encrypt=True)
    _, stats = dist.matmul(A[:64], B)
    assert stats.crypto_s > 0
    # modeled mode: crypto_s IS the model; no separate cross-check field
    assert stats.crypto_modeled_s == 0.0


class TestRealEncryption:
    """encrypt="real": genuine MEA-ECC ciphertexts cross the simulated wire
    — outputs bit-identical to the unencrypted round, crypto cost measured."""

    @pytest.mark.parametrize("scheme,kwargs", [
        ("spacdc", {"t_colluding": 1}),
        ("mds", {}),
    ])
    def test_bit_identical_fused_or_default(self, scheme, kwargs):
        plain = DistributedMatmul(scheme, 10, 4, n_stragglers=2, seed=3,
                                  **kwargs)
        real = DistributedMatmul(scheme, 10, 4, n_stragglers=2, seed=3,
                                 encrypt="real", **kwargs)
        o1, s1 = plain.matmul(A, B, round_idx=1)
        o2, s2 = real.matmul(A, B, round_idx=1)
        np.testing.assert_array_equal(o1, o2)
        assert s1.crypto_s == 0.0 and s2.crypto_s > 0.0

    def test_bit_identical_loop_path(self):
        plain = DistributedMatmul("spacdc", 10, 4, t_colluding=1,
                                  n_stragglers=2, seed=3, fused=False)
        real = DistributedMatmul("spacdc", 10, 4, t_colluding=1,
                                 n_stragglers=2, seed=3, fused=False,
                                 encrypt="real")
        o1, _ = plain.matmul(A, B, round_idx=1)
        o2, s2 = real.matmul(A, B, round_idx=1)
        np.testing.assert_array_equal(o1, o2)
        assert s2.crypto_s > 0.0

    def test_crypto_measured_not_extrapolated(self):
        real = DistributedMatmul("spacdc", 8, 4, t_colluding=1,
                                 n_stragglers=1, seed=0, encrypt="real")
        real.matmul(A, B, round_idx=0)          # warm: jit + EC tables
        _, stats = real.matmul(A, B, round_idx=1)
        # measured wall time, with the modeled estimate as a cross-check
        assert stats.crypto_s > 0.0
        assert stats.crypto_modeled_s > 0.0
        assert stats.crypto_s != stats.crypto_modeled_s

    def test_compiles_once_per_shape_class(self):
        real = DistributedMatmul("spacdc", 8, 4, t_colluding=1,
                                 n_stragglers=1, seed=0, encrypt="real")
        real.matmul(A, B, round_idx=0)
        traces = real.trace_count
        assert traces > 0
        for r in range(1, 4):                   # straggler churn, same shapes
            real.matmul(A, B, round_idx=r)
        assert real.trace_count == traces

    def test_default_transport_is_stream_hardened(self):
        """The static session channel must not reuse one paper-mode mask
        across messages — real mode defaults to stream + per-message
        nonces (paper stays opt-in for reproduction study)."""
        real = DistributedMatmul("spacdc", 6, 3, t_colluding=1,
                                 encrypt="real")
        assert real._mea.mode == "stream"

    def test_invalid_mode_rejected(self):
        with pytest.raises(ValueError):
            DistributedMatmul("spacdc", 6, 3, t_colluding=1,
                              encrypt="quantum")
