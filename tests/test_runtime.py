"""Master/worker runtime + straggler model behaviour (the paper's §VII-B
experimental apparatus)."""

import numpy as np
import pytest

from repro.data.mnist import synthetic_mnist
from repro.runtime import StragglerModel
from repro.runtime.master_worker import CodedMaster, DistributedMatmul

rng = np.random.default_rng(0)
A = rng.standard_normal((256, 64)).astype(np.float32)
B = rng.standard_normal((64, 32)).astype(np.float32)


def test_straggler_model_deterministic():
    s = StragglerModel(10, 3, seed=1)
    np.testing.assert_array_equal(s.delays(5), s.delays(5))
    assert (s.delays(5) != s.delays(6)).any()


def test_straggler_count():
    s = StragglerModel(20, 5, delay_s=1.0)
    d = s.delays(0)
    assert (d > 0.5).sum() == 5


@pytest.mark.parametrize("scheme,kwargs", [
    ("conv", {}),
    ("mds", {}),
    ("matdot", {}),
    ("spacdc", {"t_colluding": 1}),
])
def test_distributed_matmul_accuracy(scheme, kwargs):
    dist = DistributedMatmul(scheme, n_workers=10, k_blocks=4,
                             n_stragglers=2, **kwargs)
    out, stats = dist.matmul(A, B)
    rel = np.abs(out - A @ B).max() / np.abs(A @ B).max()
    tol = 0.25 if scheme == "spacdc" else 1e-2
    assert rel < tol, (scheme, rel)
    assert stats.total_s > 0


def test_conv_waits_for_stragglers():
    """The uncoded baseline pays the straggler delay; coded schemes don't."""
    conv = DistributedMatmul("conv", 10, 4, n_stragglers=2, seed=3)
    mds = DistributedMatmul("mds", 10, 4, n_stragglers=2, seed=3)
    _, s_conv = conv.matmul(A, B, round_idx=1)
    _, s_mds = mds.matmul(A, B, round_idx=1)
    assert s_conv.compute_wait_s > s_mds.compute_wait_s


def test_spacdc_rateless_vs_threshold_collision():
    """Paper's key scenario: when stragglers push survivors below the MDS
    recovery threshold, MDS must wait for a straggler — SPACDC proceeds."""
    n, k, s = 12, 10, 4   # threshold 10 > 12-4=8 survivors
    mds = DistributedMatmul("mds", n, k, n_stragglers=s, seed=7)
    spa = DistributedMatmul("spacdc", n, k, t_colluding=1, n_stragglers=s, seed=7)
    _, st_mds = mds.matmul(A, B, round_idx=2)
    _, st_spa = spa.matmul(A, B, round_idx=2)
    assert st_spa.compute_wait_s < st_mds.compute_wait_s


def test_coded_master_trains():
    xtr, ytr, xte, yte = synthetic_mnist(n_train=1024, n_test=256)
    dist = DistributedMatmul("spacdc", n_workers=8, k_blocks=4,
                             t_colluding=1, n_stragglers=1)
    m = CodedMaster((784, 64, 10), dist, lr=0.1)
    for ep in range(2):
        for i in range(0, 1024, 256):
            loss, el = m.train_batch(xtr[i:i + 256], ytr[i:i + 256])
    assert m.accuracy(xte, yte) > 0.8


def test_crypto_overhead_accounted():
    dist = DistributedMatmul("spacdc", 6, 3, t_colluding=1, encrypt=True)
    _, stats = dist.matmul(A[:64], B)
    assert stats.crypto_s > 0
    # modeled mode: crypto_s IS the model; no separate cross-check field
    assert stats.crypto_modeled_s == 0.0


class TestRealEncryption:
    """encrypt="real": genuine MEA-ECC ciphertexts cross the simulated wire
    — outputs bit-identical to the unencrypted round, crypto cost measured."""

    @pytest.mark.parametrize("scheme,kwargs", [
        ("spacdc", {"t_colluding": 1}),
        ("mds", {}),
    ])
    def test_bit_identical_fused_or_default(self, scheme, kwargs):
        plain = DistributedMatmul(scheme, 10, 4, n_stragglers=2, seed=3,
                                  **kwargs)
        real = DistributedMatmul(scheme, 10, 4, n_stragglers=2, seed=3,
                                 encrypt="real", **kwargs)
        o1, s1 = plain.matmul(A, B, round_idx=1)
        o2, s2 = real.matmul(A, B, round_idx=1)
        np.testing.assert_array_equal(o1, o2)
        assert s1.crypto_s == 0.0 and s2.crypto_s > 0.0

    def test_bit_identical_loop_path(self):
        plain = DistributedMatmul("spacdc", 10, 4, t_colluding=1,
                                  n_stragglers=2, seed=3, fused=False)
        real = DistributedMatmul("spacdc", 10, 4, t_colluding=1,
                                 n_stragglers=2, seed=3, fused=False,
                                 encrypt="real")
        o1, _ = plain.matmul(A, B, round_idx=1)
        o2, s2 = real.matmul(A, B, round_idx=1)
        np.testing.assert_array_equal(o1, o2)
        assert s2.crypto_s > 0.0

    def test_crypto_measured_not_extrapolated(self):
        real = DistributedMatmul("spacdc", 8, 4, t_colluding=1,
                                 n_stragglers=1, seed=0, encrypt="real")
        real.matmul(A, B, round_idx=0)          # warm: jit + EC tables
        _, stats = real.matmul(A, B, round_idx=1)
        # measured wall time, with the modeled estimate as a cross-check
        assert stats.crypto_s > 0.0
        assert stats.crypto_modeled_s > 0.0
        assert stats.crypto_s != stats.crypto_modeled_s

    def test_compiles_once_per_shape_class(self):
        real = DistributedMatmul("spacdc", 8, 4, t_colluding=1,
                                 n_stragglers=1, seed=0, encrypt="real")
        real.matmul(A, B, round_idx=0)
        traces = real.trace_count
        assert traces > 0
        for r in range(1, 4):                   # straggler churn, same shapes
            real.matmul(A, B, round_idx=r)
        assert real.trace_count == traces

    def test_default_transport_is_stream_hardened(self):
        """The static session channel must not reuse one paper-mode mask
        across messages — real mode defaults to stream + per-message
        nonces (paper stays opt-in for reproduction study)."""
        real = DistributedMatmul("spacdc", 6, 3, t_colluding=1,
                                 encrypt="real")
        assert real._mea.mode == "stream"

    def test_invalid_mode_rejected(self):
        with pytest.raises(ValueError):
            DistributedMatmul("spacdc", 6, 3, t_colluding=1,
                              encrypt="quantum")
