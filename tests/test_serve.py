"""Continuous-batching coded serving: coded-vs-uncoded parity across
``coded_layers`` settings, compile-count under slot churn, scheduling
semantics, ServeSpec validation, and the report's latency accounting."""

import dataclasses
import math

import numpy as np
import pytest

from repro.api import (ClusterSpec, CodeSpec, CryptoSpec, PrivacySpec,
                       ServeSpec, Session, StragglerSpec, TransportSpec,
                       WaitSpec)
from repro.runtime.serve_loop import (ContinuousBatcher, Request,
                                      poisson_workload)


def exact_spec(coded_layers="all", *, backend="virtual", max_slots=4,
               eos_id=None, crypto=None):
    """MDS + wait-for-all + no stragglers: the decode is EXACT (linear
    Vandermonde inversion), so coded greedy tokens must be bit-identical
    to the plain path — the parity configurations."""
    kw = dict(code=CodeSpec(scheme="mds", n_workers=8, k_blocks=4),
              wait=WaitSpec(policy="first_k", k=8),
              straggler=StragglerSpec(n_stragglers=0),
              transport=TransportSpec(backend=backend),
              serve=ServeSpec(coded_layers=coded_layers, max_slots=max_slots,
                              eos_id=eos_id))
    if crypto is not None:
        kw["crypto"] = crypto
    return ClusterSpec(**kw)


def ragged_requests(n=5, vocab=256, seed=3, rate=None):
    rng = np.random.default_rng(seed)
    arr = np.zeros(n)
    if rate:
        arr = np.cumsum(rng.exponential(1.0 / rate, n))
        arr -= arr[0]
    return [Request(rid=i,
                    prompt=rng.integers(1, vocab, int(rng.integers(3, 9)))
                    .astype(np.int32),
                    gen=int(rng.integers(2, 7)), arrival_s=float(arr[i]))
            for i in range(n)]


def serve_tokens(spec, requests, **kw):
    with Session(spec) as s:
        rep = s.serve(arch="qwen2-7b", tiny=True, requests=requests,
                      check_agreement=False, **kw)
    return rep


# --------------------------------------------------------------------------
# parity: coded == uncoded, token for token
# --------------------------------------------------------------------------

class TestCodedServeParity:
    @pytest.mark.parametrize("coded_layers",
                             ["unembed", "attn", "ffn", "all"])
    def test_tokens_bit_identical_across_coded_layers(self, coded_layers):
        reqs = ragged_requests(n=5)
        ref = serve_tokens(exact_spec("none"), reqs)
        rep = serve_tokens(exact_spec(coded_layers), reqs)
        assert rep.mode == "instep"
        np.testing.assert_array_equal(ref.tokens, rep.tokens)

    def test_parity_holds_on_mla_arch(self):
        # deepseek: MLA qkv/o sites + dense-FFN positions of the MoE stack
        reqs = ragged_requests(n=3, seed=5)
        with Session(exact_spec("none")) as s:
            ref = s.serve(arch="deepseek-v2-lite-16b", tiny=True,
                          requests=reqs, check_agreement=False)
        with Session(exact_spec("all")) as s:
            rep = s.serve(arch="deepseek-v2-lite-16b", tiny=True,
                          requests=reqs, check_agreement=False)
        np.testing.assert_array_equal(ref.tokens, rep.tokens)

    def test_parity_with_real_encryption(self):
        # encrypt="real": every site's two transfers cross the one-dispatch
        # cipher in-step; the bits codec keeps the round trip lossless, so
        # tokens stay bit-identical and crypto time is attributed
        reqs = ragged_requests(n=4)
        ref = serve_tokens(exact_spec("none"), reqs)
        rep = serve_tokens(
            exact_spec("all", crypto=CryptoSpec(encrypt="real")), reqs)
        np.testing.assert_array_equal(ref.tokens, rep.tokens)
        assert all(st.crypto_s > 0 for st in rep.step_stats)
        assert all(st.dispatches == 1 for st in rep.step_stats)

    def test_parity_on_threads_transport(self):
        # real transports keep the PR 5 semantics: unembed as a real round
        reqs = ragged_requests(n=3)
        ref = serve_tokens(exact_spec("none"), reqs)
        rep = serve_tokens(exact_spec("unembed", backend="threads"), reqs)
        assert rep.mode == "round"
        np.testing.assert_array_equal(ref.tokens, rep.tokens)

    def test_session_agreement_diagnostic(self):
        # the built-in diagnostic replays the workload uncoded and compares
        with Session(exact_spec("all")) as s:
            rep = s.serve(arch="qwen2-7b", tiny=True,
                          requests=ragged_requests(n=3))
        assert rep.argmax_agreement == 1.0

    def test_spacdc_deadline_agreement_is_bounded_not_exact(self):
        # the paper's own scheme is APPROXIMATED coded computing: under a
        # deadline the decode is a rational approximation, so agreement is
        # a diagnostic in [0, 1], not an exactness guarantee
        spec = ClusterSpec.serve_deadline(t_budget=0.008,
                                          coded_layers="unembed",
                                          max_slots=4)
        with Session(spec) as s:
            rep = s.serve(arch="qwen2-7b", tiny=True, batch=2, prompt_len=6,
                          gen=4, seed=0)
        assert 0.0 <= rep.argmax_agreement <= 1.0
        assert rep.steps_within_budget == len(rep.step_stats)


# --------------------------------------------------------------------------
# compilation: churn never retraces
# --------------------------------------------------------------------------

class TestServeCompileCount:
    def test_churn_never_retraces_within_buckets(self):
        # 12 ragged Poisson requests through 4 slots: admissions and
        # evictions churn the in-flight set every few steps, but the step
        # program only ever sees pow2 bucket widths — compiles are bounded
        # by the number of DISTINCT buckets, not the churn
        reqs = ragged_requests(n=12, seed=11, rate=150.0)
        rep = serve_tokens(exact_spec("all"), reqs)
        n_buckets = len(set((1, 2, 4)) & set(
            1 << i for i in range(3)))  # possible buckets for 4 slots: 1,2,4
        assert rep.trace_count <= 3, \
            (rep.trace_count, n_buckets)
        assert len(rep.step_stats) > rep.trace_count * 3

    def test_second_serve_reuses_compiled_steps(self):
        reqs = ragged_requests(n=4, seed=2)
        with Session(exact_spec("all")) as s:
            rep1 = s.serve(arch="qwen2-7b", tiny=True, requests=reqs,
                           check_agreement=False)
            rep2 = s.serve(arch="qwen2-7b", tiny=True, requests=reqs,
                           check_agreement=False)
        assert rep1.trace_count > 0
        assert rep2.trace_count == rep1.trace_count   # zero new traces

    def test_one_round_one_dispatch_per_step(self):
        rep = serve_tokens(exact_spec("all"), ragged_requests(n=4))
        assert all(st.dispatches == 1 for st in rep.step_stats)
        assert all(st.n_waited >= 1 for st in rep.step_stats)


# --------------------------------------------------------------------------
# scheduling semantics
# --------------------------------------------------------------------------

class TestContinuousBatching:
    def test_poisson_workload_shapes(self):
        reqs = poisson_workload(16, rate_rps=50.0, prompt_len=12, gen=8,
                                vocab=256, seed=0, ragged=True)
        assert len(reqs) == 16
        assert reqs[0].arrival_s == 0.0
        assert all(reqs[i].arrival_s <= reqs[i + 1].arrival_s
                   for i in range(15))
        assert all(2 <= len(r.prompt) <= 12 and 1 <= r.gen <= 8
                   for r in reqs)

    def test_every_request_served_with_full_budget(self):
        reqs = ragged_requests(n=7, seed=9, rate=100.0)
        rep = serve_tokens(exact_spec("all"), reqs)
        assert len(rep.requests) == 7
        got = {r.rid: r for r in rep.requests}
        for r in reqs:
            assert len(got[r.rid].tokens) == r.gen
            assert got[r.rid].first_token_s >= r.arrival_s
            assert got[r.rid].done_s >= got[r.rid].first_token_s

    def test_eos_evicts_early(self):
        # serve once to learn a token the model actually emits, then
        # declare it EOS and serve again: the request must stop early
        reqs = [Request(rid=0, prompt=np.arange(1, 7, dtype=np.int32),
                        gen=8)]
        free = serve_tokens(exact_spec("all"), reqs)
        eos = int(free.requests[0].tokens[2])
        rep = serve_tokens(exact_spec("all", eos_id=eos), reqs)
        toks = rep.requests[0].tokens
        assert len(toks) <= 8
        assert eos in toks.tolist() or len(toks) == 8

    def test_continuous_beats_gated_admission(self):
        # mixed short/long requests over a Poisson trace: static batching
        # (gated) holds finished shorts hostage to the longest request
        rng = np.random.default_rng(7)
        arr = np.cumsum(rng.exponential(1 / 150.0, 12))
        arr -= arr[0]
        reqs = [Request(rid=i,
                        prompt=rng.integers(1, 256, 6).astype(np.int32),
                        gen=(24 if i % 4 == 0 else 3),
                        arrival_s=float(arr[i]))
                for i in range(12)]
        cont = serve_tokens(exact_spec("all"), reqs)
        gated = serve_tokens(exact_spec("all"), reqs, admission="gated")
        assert cont.requests_per_s > gated.requests_per_s
        assert len(cont.requests) == len(gated.requests) == 12

    def test_gen_budget_tokens_match_uniform_legacy_shape(self):
        # uniform workload at rate 0 keeps the legacy (batch, gen) shape
        with Session(exact_spec("all")) as s:
            rep = s.serve(arch="qwen2-7b", tiny=True, batch=3, prompt_len=6,
                          gen=5, seed=0, check_agreement=False)
        assert rep.tokens.shape == (3, 5)
        assert (rep.tokens >= 0).all()           # no padding needed
        assert len(rep.step_stats) == 6 - 1 + 5  # prefill rides the steps


# --------------------------------------------------------------------------
# report accounting
# --------------------------------------------------------------------------

class TestServeReportAccounting:
    def test_latency_summaries(self):
        reqs = ragged_requests(n=6, seed=4, rate=80.0)
        rep = serve_tokens(exact_spec("all"), reqs)
        assert rep.ttft_s.shape == (6,)
        assert (rep.ttft_s > 0).all()
        assert rep.step_latency_s.shape == (len(rep.step_stats),)
        assert 0 < rep.p50_step_s <= rep.p99_step_s
        assert rep.p99_step_s <= rep.step_latency_s.max() + 1e-12
        assert rep.requests_per_s > 0
        assert rep.virtual_s >= rep.step_latency_s.sum() - 1e-9

    def test_tok_s_excludes_admission_idle(self):
        # a huge arrival gap parks the loop idle on the virtual clock;
        # busy wall (the tok_s denominator) must not contain it
        reqs = [Request(rid=0, prompt=np.arange(1, 5, dtype=np.int32),
                        gen=3, arrival_s=0.0),
                Request(rid=1, prompt=np.arange(1, 5, dtype=np.int32),
                        gen=3, arrival_s=1e3)]
        rep = serve_tokens(exact_spec("all"), reqs)
        assert rep.virtual_s > 1e3               # the gap is on the clock
        assert rep.busy_wall_s < 1e2             # ...but not in busy wall
        assert rep.tok_s == pytest.approx(
            sum(len(r.tokens) for r in rep.requests) / rep.busy_wall_s)

    def test_coded_flop_fraction_gate_shape(self):
        from repro.configs import get_config
        from repro.models.coded import coded_flop_fraction
        cfg = get_config("qwen2-7b")
        full = coded_flop_fraction(cfg, "all")
        assert full >= 0.9                       # the acceptance gate
        assert coded_flop_fraction(cfg, "none") == 0.0
        order = [coded_flop_fraction(cfg, c)
                 for c in ("unembed", "attn", "ffn", "all")]
        assert order[0] < order[1] < order[3] and order[2] < order[3]


# --------------------------------------------------------------------------
# ServeSpec surface
# --------------------------------------------------------------------------

class TestServeSpec:
    def test_round_trip(self):
        spec = exact_spec("attn", max_slots=16, eos_id=7)
        again = ClusterSpec.from_dict(spec.to_dict())
        assert again.serve == spec.serve
        assert again == spec

    def test_validation(self):
        with pytest.raises(ValueError, match="coded_layers"):
            ServeSpec(coded_layers="everything")
        with pytest.raises(ValueError, match="max_slots"):
            ServeSpec(max_slots=0)
        with pytest.raises(ValueError, match="eos_id"):
            ServeSpec(eos_id=-2)

    def test_real_transport_rejects_stacked_layers(self):
        with pytest.raises(ValueError, match="virtual"):
            exact_spec("all", backend="threads").validate()
        # unembed / none stay valid on real transports
        exact_spec("unembed", backend="threads").validate()
        exact_spec("none", backend="threads").validate()

    def test_serve_deadline_preset_carries_serve_spec(self):
        spec = ClusterSpec.serve_deadline(coded_layers="ffn", max_slots=2,
                                          eos_id=5)
        assert spec.serve == ServeSpec(coded_layers="ffn", max_slots=2,
                                       eos_id=5)

    def test_batcher_rejects_unfusable_scheme_beyond_unembed(self):
        # a non-fused scheme can't run the in-step masked decode
        import jax
        from repro.configs import tiny_config
        from repro.models import build_model
        from repro.runtime.engine import RoundEngine
        spec = dataclasses.replace(
            exact_spec("unembed"),
            code=CodeSpec(scheme="conv", n_workers=4),
            wait=WaitSpec(policy="first_k", k=4))
        engine = RoundEngine(spec)
        cfg = tiny_config("qwen2-7b")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        if not getattr(engine.scheme, "supports_fused", False):
            with pytest.raises(ValueError, match="fused"):
                ContinuousBatcher(engine, model, params,
                                  coded_layers="all", backend="virtual")
        engine.close()
