"""dist.sharding utilities + the HLO analyzer on a synthetic module."""

import numpy as np
import jax
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import add_data_axis, prune_spec
from repro.launch.hlo_analysis import analyze, _parse_computations


class FakeMesh:
    axis_names = ("data", "model")

    class _Dev:
        shape = (4, 2)
    devices = _Dev()


def test_prune_spec_drops_nondivisible():
    spec = prune_spec(P("data", "model"), (1, 64), FakeMesh())
    assert tuple(spec) == (None, "model")


def test_prune_spec_keeps_divisible():
    spec = prune_spec(P("data", "model"), (8, 64), FakeMesh())
    assert tuple(spec) == ("data", "model")


def test_prune_tuple_axes():
    spec = prune_spec(P(("data", "model"), None), (8, 3), FakeMesh())
    assert tuple(spec) == (("data", "model"), None)
    spec = prune_spec(P(("data", "model"), None), (4, 3), FakeMesh())
    assert tuple(spec) == (None, None)


def test_add_data_axis_first_free_dim():
    out = add_data_axis(P(None, "model", None), (64, 32, 48), dp_size=16)
    assert tuple(out) == ("data", "model", None)


def test_add_data_axis_skip_dims():
    out = add_data_axis(P(None, "model", None), (64, 32, 48), dp_size=16,
                        skip_dims=(0,))
    assert tuple(out) == (None, "model", "data")


def test_add_data_axis_never_double_shards():
    out = add_data_axis(P("data", None), (64, 32), dp_size=16)
    assert tuple(out) == ("data", None)


SYNTH_HLO = """
HloModule synth, entry_computation_layout={()->f32[8,8]{1,0}}

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8]{1,0} get-tuple-element(%p), index=1
  %d = f32[8,8]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,8]{1,0} all-reduce(%d), replica_groups={}, to_apply=%add
  ROOT %t = (s32[], f32[8,8]{1,0}) tuple(%i, %ar)
}

%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]{1,0}) parameter(0)
  ROOT %c = pred[] constant(false)
}

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main () -> f32[8,8] {
  %init = (s32[], f32[8,8]{1,0}) tuple()
  %w = (s32[], f32[8,8]{1,0}) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
  ROOT %out = f32[8,8]{1,0} get-tuple-element(%w), index=1
}
"""


def test_hlo_analyzer_trip_counts():
    m = analyze(SYNTH_HLO)
    # dot: 2*8*8*8 = 1024 flops, x5 trips
    assert m.flops == 1024 * 5
    # all-reduce: 8*8*4 bytes x5
    assert m.collective_bytes["all-reduce"] == 256 * 5
    assert m.collective_counts["all-reduce"] == 5


def test_hlo_parser_counts_computations():
    comps, entry = _parse_computations(SYNTH_HLO)
    assert entry == "main"
    assert set(comps) == {"body", "cond", "add", "main"}
