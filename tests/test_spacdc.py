import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import SPACDCCode, SPACDCConfig


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(0)
    return jnp.asarray(rng.standard_normal((40, 16)), jnp.float32)


def _exact(code, x, f):
    return jax.vmap(f)(code.split_blocks(x))


def test_paper_illustrating_example(data):
    """§V-A: N=8 workers, K=2, S=T=1, f(X)=X Xᵀ."""
    code = SPACDCCode(SPACDCConfig(n_workers=8, k_blocks=2, t_colluding=1,
                                   noise_scale=1.0))
    f = lambda a: a @ a.T
    exact = _exact(code, data, f)
    # one straggler: drop worker 5
    resp = [0, 1, 2, 3, 4, 6, 7]
    approx = code.run(data, f, responders=resp)
    scale = float(jnp.max(jnp.abs(exact)))
    assert float(jnp.max(jnp.abs(approx - exact))) / scale < 0.25


def test_no_recovery_threshold(data):
    """Decoding succeeds for ANY responder count — the paper's key claim."""
    code = SPACDCCode(SPACDCConfig(n_workers=12, k_blocks=3))
    f = lambda a: a @ a.T
    shards = code.encode(data)
    results = jax.vmap(f)(shards)
    prev = None
    for n_resp in (3, 6, 9, 12):
        out = code.decode(results[:n_resp], list(range(n_resp)))
        assert out.shape[0] == 3
        assert bool(jnp.all(jnp.isfinite(out)))


def test_accuracy_degrades_gracefully(data):
    code = SPACDCCode(SPACDCConfig(n_workers=24, k_blocks=4))
    f = lambda a: a @ a.T
    exact = _exact(code, data, f)
    shards = code.encode(data)
    results = jax.vmap(f)(shards)
    scale = float(jnp.sqrt(jnp.mean(exact ** 2)))
    errs = []
    for n_resp in (24, 18, 12):
        out = code.decode(results[:n_resp], list(range(n_resp)))
        errs.append(float(jnp.sqrt(jnp.mean((out - exact) ** 2))) / scale)
    assert errs[0] < 0.05, errs
    assert errs[0] <= errs[1] * 1.5 and errs[1] <= errs[2] * 1.5, errs


def test_masked_decode_matches_indexed(data):
    code = SPACDCCode(SPACDCConfig(n_workers=10, k_blocks=3, t_colluding=1))
    f = lambda a: jnp.tanh(a) @ jnp.tanh(a).T
    shards = code.encode(data)
    results = jax.vmap(f)(shards)
    resp = np.asarray([0, 2, 3, 5, 6, 9])
    mask = np.zeros(10, np.float32)
    mask[resp] = 1
    d1 = code.decode(results[resp], resp)
    d2 = code.decode_masked(results, jnp.asarray(mask))
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), atol=1e-4)


def test_nonlinear_function_support(data):
    """Arbitrary (non-polynomial) f — beyond what LCC/Polynomial codes allow."""
    code = SPACDCCode(SPACDCConfig(n_workers=30, k_blocks=3))
    f = lambda a: jax.nn.gelu(a @ a.T)
    exact = _exact(code, data, f)
    approx = code.run(data, f)
    scale = float(jnp.max(jnp.abs(exact))) + 1e-9
    assert float(jnp.max(jnp.abs(approx - exact))) / scale < 0.15


def test_zero_padding_roundtrip():
    code = SPACDCCode(SPACDCConfig(n_workers=8, k_blocks=3))
    x = jnp.ones((10, 4))  # 10 rows not divisible by 3
    blocks = code.split_blocks(x)
    assert blocks.shape == (3, 4, 4)
    assert float(blocks.sum()) == 40.0  # padding is zeros


def test_encode_is_jittable(data):
    code = SPACDCCode(SPACDCConfig(n_workers=8, k_blocks=2, t_colluding=1))
    enc = jax.jit(lambda x, k: code.encode(x, key=k))
    out = enc(data, jax.random.PRNGKey(1))
    assert out.shape == (8, 20, 16)
