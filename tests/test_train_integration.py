"""End-to-end behaviour: a tiny LM actually trains; coded aggregation ==
plain DP when all respond; straggler masks keep training stable; the
weighted-loss identity matches explicit per-block gradient decoding."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import tiny_config
from repro.core import BerrutGradientCode
from repro.data.pipeline import TokenPipeline
from repro.launch.steps import build_serve_step, build_train_step
from repro.models import build_model
from repro.optim import adamw

KEY = jax.random.PRNGKey(0)


def _setup(arch="phi3-mini-3.8b", coded=True, nb=4, accum=2):
    cfg = tiny_config(arch)
    model = build_model(cfg)
    params = model.init(KEY)
    opt = adamw(3e-3, weight_decay=0.0)
    state = opt.init(params)
    gcode = BerrutGradientCode(n_shards=nb, n_blocks=nb) if coded else None
    step = jax.jit(build_train_step(model, opt, accum=accum, gcode=gcode))
    pipe = TokenPipeline(cfg.vocab_size, seq_len=32, global_batch=nb * accum * 2)
    return cfg, model, params, state, step, pipe, nb


def test_loss_decreases_coded():
    cfg, model, params, state, step, pipe, nb = _setup(coded=True)
    mask = jnp.ones((nb,), jnp.float32)
    losses = []
    for i in range(12):
        params, state, m = step(params, state, pipe.batch_at(i), mask)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], losses


def test_coded_full_mask_matches_uncoded():
    _, model, p1, s1, step_c, pipe, nb = _setup(coded=True)
    _, _, p2, s2, step_u, _, _ = _setup(coded=False)
    mask = jnp.ones((nb,), jnp.float32)
    b = pipe.batch_at(0)
    p1n, _, m1 = step_c(p1, s1, b, mask)
    p2n, _, m2 = step_u(p2, s2, b, mask)
    # same data, full mask: the coded decode weights average the same blocks
    # (weights sum to 1, near-uniform) -> losses match closely
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 0.05


def test_straggler_mask_stable():
    cfg, model, params, state, step, pipe, nb = _setup(coded=True)
    rng = np.random.default_rng(0)
    losses = []
    for i in range(10):
        mask = np.ones(nb, np.float32)
        if i % 2:
            mask[rng.integers(0, nb)] = 0.0   # a straggler every other step
        params, state, m = step(params, state, pipe.batch_at(i),
                                jnp.asarray(mask))
        losses.append(float(m["loss"]))
        assert np.isfinite(losses[-1])
    assert losses[-1] < losses[0] * 1.1


def test_weighted_loss_identity():
    """∇Σ w_n L_n == Σ w_n ∇L_n — the identity the coded path relies on."""
    cfg = tiny_config("qwen2-7b")
    model = build_model(cfg)
    params = model.init(KEY)
    pipe = TokenPipeline(cfg.vocab_size, 16, 4)
    batch = pipe.batch_at(0)
    blocks = {k: v.reshape(4, 1, *v.shape[1:]) for k, v in batch.items()}
    w = jnp.asarray([0.4, 0.3, 0.2, 0.1])

    def weighted(p):
        losses = jax.vmap(lambda bb: model.loss_fn(p, bb)[0])(blocks)
        return jnp.sum(w * losses)

    g1 = jax.grad(weighted)(params)
    g2 = None
    for i in range(4):
        bi = {k: v[i] for k, v in blocks.items()}
        gi = jax.grad(lambda p: model.loss_fn(p, bi)[0])(params)
        gi = jax.tree.map(lambda x: w[i] * x, gi)
        g2 = gi if g2 is None else jax.tree.map(jnp.add, g2, gi)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=2e-2)


def test_compression_path_trains():
    cfg = tiny_config("phi3-mini-3.8b")
    model = build_model(cfg)
    params = model.init(KEY)
    opt = adamw(3e-3, weight_decay=0.0)
    state = opt.init(params)
    step = jax.jit(build_train_step(model, opt, accum=1, compress=True))
    pipe = TokenPipeline(cfg.vocab_size, 32, 4)
    mask = jnp.ones((1,), jnp.float32)
    losses = [float(step(params, state, pipe.batch_at(i), mask)[2]["loss"])
              for i in range(1)]
    l0 = losses[0]
    for i in range(10):
        params, state, m = step(params, state, pipe.batch_at(i), mask)
    assert float(m["loss"]) < l0


def test_serve_step_greedy():
    cfg = tiny_config("qwen3-14b")
    model = build_model(cfg)
    params = model.init(KEY)
    serve = jax.jit(build_serve_step(model))
    cache = model.init_cache(2, 32)
    tok = jnp.ones((2, 1), jnp.int32)
    for pos in range(4):
        tok, cache = serve(params, cache, tok, pos)
    assert tok.shape == (2, 1) and tok.dtype == jnp.int32
