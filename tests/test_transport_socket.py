"""Socket transport: wire codec, process mesh robustness, cross-backend
parity (tiered to stay fast on one core — mesh tests use N=3-6 workers)."""

import json
import signal
import socket
import time

import numpy as np
import pytest

from repro.api import ClusterSpec, FaultSpec, Session, TransportSpec
from repro.runtime import wire
from repro.runtime.faults import (FaultInjectingTransport, FaultPlan,
                                  ResultDropped, WorkerHealth)
from repro.runtime.scheduler import retry_backoff
from repro.runtime.socket_transport import SocketTransport
from repro.runtime.straggler import StragglerModel
from repro.runtime.tasks import MatmulTask
from repro.runtime.transport import available_backends, build_transport


# --------------------------------------------------------------------------
# wire codec (no processes)
# --------------------------------------------------------------------------

class TestWireCodec:
    def test_value_roundtrip(self):
        vals = [
            None, True, False, 0, -7, 2 ** 62,
            2 ** 255 + 12345, -(2 ** 200),          # EC-coordinate scale
            1.5, -0.0, "héllo", b"\x00\xff",
            (1, "a", None), [1.0, 2.0], {"k": (1, 2), "n": None},
            np.arange(12, dtype=np.float32).reshape(3, 4),
            np.asarray([], dtype=np.float64),
        ]
        for v in vals:
            got = wire.loads(wire.dumps(v))
            if isinstance(v, np.ndarray):
                assert got.dtype == v.dtype and np.array_equal(got, v)
            else:
                assert got == v and type(got) is type(v)

    def test_array_bits_exact(self):
        rng = np.random.default_rng(0)
        a = rng.standard_normal((7, 5)).astype(np.float32)
        got = wire.loads(wire.dumps(a))
        assert got.tobytes() == a.tobytes()

    def test_ciphertext_roundtrip_no_double_serialization(self):
        from repro.crypto import MEAECC, generate_keypair
        mea = MEAECC(codec="bits")
        kp = generate_keypair()
        x = np.random.default_rng(1).standard_normal((16, 8)) \
            .astype(np.float32)
        ct = mea.encrypt(x, kp.pk, sender=kp, nonce=5)
        got = wire.loads(wire.dumps(ct))
        # the limb plane crosses verbatim: decrypt of the wire copy is
        # bit-identical to decrypt of the original
        assert got.payload.tobytes() == np.asarray(ct.payload).tobytes()
        assert np.array_equal(mea.decrypt(got, kp), mea.decrypt(ct, kp))
        # no re-encode: wire size = limb bytes + a small constant header
        encoded, limb_bytes = wire.ciphertext_wire_overhead(ct)
        assert encoded - limb_bytes < 256

    def test_frame_roundtrip_over_socketpair(self):
        a, b = socket.socketpair()
        payload = wire.dumps({"x": np.ones(3, np.float32)})
        a.sendall(wire.pack_frame(wire.RESULT, 3, 42, payload))
        fr = wire.read_frame(b)
        assert (fr.type, fr.worker, fr.sub, fr.crc_ok) == \
            (wire.RESULT, 3, 42, True)
        assert np.array_equal(wire.loads(fr.payload)["x"],
                              np.ones(3, np.float32))
        a.close(), b.close()

    def test_tampered_frame_fails_crc_not_routing(self):
        a, b = socket.socketpair()
        frame = wire.pack_frame(wire.RESULT, 1, 7, wire.dumps(
            np.arange(64, dtype=np.float32)))
        a.sendall(wire.tamper_frame(frame,
                                    np.random.default_rng(0)))
        fr = wire.read_frame(b)
        # header intact (the frame still routes), payload integrity gone
        assert (fr.type, fr.worker, fr.sub) == (wire.RESULT, 1, 7)
        assert fr.crc_ok is False
        a.close(), b.close()

    def test_bad_magic_raises(self):
        a, b = socket.socketpair()
        a.sendall(b"XXXX" + bytes(wire.HEADER_SIZE - 4))
        with pytest.raises(wire.FrameError):
            wire.read_frame(b)
        a.close(), b.close()

    def test_unknown_tag_raises(self):
        with pytest.raises(wire.FrameError):
            wire.loads(b"Z")


# --------------------------------------------------------------------------
# jittered backoff + health serialization (satellites)
# --------------------------------------------------------------------------

class TestBackoffJitter:
    def test_no_rng_returns_deterministic_cap(self):
        assert retry_backoff(1, 0.01, 0.08) == pytest.approx(0.01)
        assert retry_backoff(3, 0.01, 0.08) == pytest.approx(0.04)
        assert retry_backoff(10, 0.01, 0.08) == pytest.approx(0.08)

    def test_full_jitter_bounded_and_seeded(self):
        draws = [retry_backoff(3, 0.01, 0.08,
                               rng=np.random.default_rng(7))
                 for _ in range(3)]
        assert draws[0] == draws[1] == draws[2]      # reproducible
        rng = np.random.default_rng(123)
        xs = [retry_backoff(3, 0.01, 0.08, rng=rng) for _ in range(200)]
        assert all(0.0 <= x <= 0.04 for x in xs)
        assert len(set(xs)) > 100                    # actually jittered

    def test_defended_round_backoff_reproducible(self):
        # same spec twice -> identical jittered wait accounting
        def run():
            spec = ClusterSpec.from_dict({
                "code": {"scheme": "spacdc", "n_workers": 6, "k_blocks": 2},
                "straggler": {"n_stragglers": 0, "delay_s": 0.01},
                "fault": {"crash_rate": 0.25, "handle": True, "seed": 139,
                          "max_retries": 3},
                "seed": 7,
            })
            a = np.random.default_rng(0).standard_normal((8, 6)) \
                .astype(np.float32)
            b = np.random.default_rng(1).standard_normal((6, 4)) \
                .astype(np.float32)
            with Session(spec) as s:
                out, stats = s.matmul(a, b, round_idx=0)
            return out, stats
        o1, s1 = run()
        o2, s2 = run()
        assert s1.retries == s2.retries >= 1
        # wait accounting includes a MEASURED worker-compute sample, so
        # only the decode bits (and the retry trace) are exactly equal
        assert np.array_equal(o1, o2)


class TestHealthToDict:
    def test_json_roundtrip(self):
        h = WorkerHealth(3)
        h.record_ok(0, 0.05)
        h.record_crash(1, 0)
        h.record_crash(1, 1)      # -> quarantined
        h.record_drop(2, 1)
        d = json.loads(json.dumps(h.to_dict()))
        assert d["n_workers"] == 3
        w1 = d["workers"][1]
        assert w1["n_crash"] == 2 and w1["n_quarantines"] == 1
        assert d["workers"][0]["ewma_latency_s"] == pytest.approx(0.05)
        assert d["workers"][2]["n_drop"] == 1
        # never-measured latency serializes as null, not NaN
        assert d["workers"][1]["ewma_latency_s"] is None


# --------------------------------------------------------------------------
# registry + spec plumbing
# --------------------------------------------------------------------------

class TestRegistry:
    def test_socket_registered(self):
        assert "socket" in available_backends()

    def test_unknown_backend_error_enumerates_registry(self):
        st = StragglerModel(4, 1, seed=0)
        with pytest.raises(ValueError, match="socket"):
            build_transport("carrier-pigeon", 4, st)

    def test_transport_spec_socket_options(self):
        ts = TransportSpec(backend="socket", heartbeat_s=0.1,
                           liveness_timeout_s=0.5)
        opts = ts.backend_options()
        assert opts["heartbeat_s"] == 0.1
        assert TransportSpec(backend="threads").backend_options() == {}

    def test_liveness_must_exceed_heartbeat(self):
        with pytest.raises(ValueError, match="liveness"):
            TransportSpec(backend="socket", heartbeat_s=0.5,
                          liveness_timeout_s=0.5)

    def test_os_level_requires_socket_backend(self):
        with pytest.raises(ValueError, match="os_level"):
            ClusterSpec.from_dict({
                "code": {"scheme": "spacdc", "n_workers": 4, "k_blocks": 2},
                "fault": {"crash_rate": 0.2, "os_level": True},
                "transport": {"backend": "threads"},
            })


# --------------------------------------------------------------------------
# the process mesh
# --------------------------------------------------------------------------

def _mesh(n=3, **kw):
    st = StragglerModel(n, 0, delay_s=0.01, seed=0)
    kw.setdefault("heartbeat_s", 0.1)
    kw.setdefault("liveness_timeout_s", 1.0)
    kw.setdefault("connect_timeout_s", 60.0)
    return SocketTransport(n, st, **kw)


_B = np.arange(12, dtype=np.float32).reshape(3, 4)


def _shards(n):
    return [np.full((2, 3), i + 1, np.float32) for i in range(n)]


class TestSocketMesh:
    def test_clean_round_all_respond(self):
        tr = _mesh(3)
        try:
            h = tr.submit_round(_shards(3), MatmulTask(_B), 0)
            evs = list(h.events())
            assert sorted(e.worker for e in evs) == [0, 1, 2]
            for e in evs:
                assert np.array_equal(h.result(e.worker),
                                      _shards(3)[e.worker] @ _B)
            h.finish()
        finally:
            tr.close()

    def test_kill_mid_round_and_reconnect_after_crash(self):
        tr = _mesh(3)
        try:
            tr.start()
            # round 1: SIGKILL worker 0 right after dispatch — the round
            # must END (no hang) with the two survivors
            plan = FaultPlan(crash=np.array([True, False, False]),
                             drop=np.zeros(3, bool),
                             corrupt=np.zeros(3, bool),
                             spike_s=np.zeros(3))
            tr.schedule_os_faults(0, plan, FaultSpec(), 0)
            h = tr.submit_round(_shards(3), MatmulTask(_B), 0)
            evs = list(h.events())
            h.finish()
            assert sorted(e.worker for e in evs) == [1, 2]
            assert tr.stats["kills"] == 1
            # respawn + re-registration: worker 0 comes back and serves
            deadline = time.time() + 30
            while time.time() < deadline:
                c = tr._conns.get(0)
                if c is not None and c.alive and c.generation >= 1:
                    break
                time.sleep(0.05)
            h2 = tr.submit_round(_shards(3), MatmulTask(_B), 1)
            evs2 = list(h2.events())
            h2.finish()
            assert sorted(e.worker for e in evs2) == [0, 1, 2]
            assert np.array_equal(h2.result(0), _shards(3)[0] @ _B)
            assert tr.stats["respawns"] >= 1
        finally:
            tr.close()

    def test_tampered_frame_reported_dropped(self):
        tr = _mesh(3)
        try:
            plan = FaultPlan(crash=np.zeros(3, bool),
                             drop=np.array([False, True, False]),
                             corrupt=np.zeros(3, bool),
                             spike_s=np.zeros(3))
            tr.schedule_os_faults(0, plan, FaultSpec(), 0)
            h = tr.submit_round(_shards(3), MatmulTask(_B), 0)
            evs = list(h.events())
            h.finish()
            # the tampered worker still ARRIVES (its frame routed), but
            # its payload failed CRC -> the result was dropped in transit
            assert sorted(e.worker for e in evs) == [0, 1, 2]
            with pytest.raises(ResultDropped):
                h.result(1)
            assert np.array_equal(h.result(0), _shards(3)[0] @ _B)
            assert tr.stats["crc_failures"] == 1
        finally:
            tr.close()

    def test_liveness_deadline_ends_round_on_frozen_worker(self):
        tr = _mesh(3, liveness_timeout_s=0.8)
        try:
            tr.start()
            pid = tr.worker_pid(2)
            import os
            os.kill(pid, signal.SIGSTOP)
            try:
                time.sleep(0.2)
                t0 = time.perf_counter()
                h = tr.submit_round(_shards(3), MatmulTask(_B), 0)
                evs = list(h.events())
                h.finish()
                took = time.perf_counter() - t0
                assert sorted(e.worker for e in evs) == [0, 1]
                assert took < 10.0          # bounded by liveness, no hang
                assert tr.stats["liveness_expired"] >= 1
            finally:
                os.kill(pid, signal.SIGCONT)
        finally:
            tr.close()

    def test_orphaned_results_reaped(self):
        st = StragglerModel(3, 0, delay_s=0.01, seed=0)
        tr = SocketTransport(3, st, heartbeat_s=0.1, liveness_timeout_s=2.0)
        try:
            tr.start()
            slow = [np.full((2, 3), 1, np.float32)] * 3
            # worker 2 sleeps long via an injected straggler delay: give
            # up on the round early, its late result must be reaped
            class _Slow:
                n_workers, n_stragglers = 3, 0
                def delays(self, r):
                    return np.array([0.0, 0.0, 1.0])
            tr.straggler = _Slow()
            h = tr.submit_round(slow, MatmulTask(_B), 0,
                                budget=0.4, min_ready=1)
            evs = list(h.events())
            h.finish()                       # round forgotten here
            assert len(evs) == 2
            deadline = time.time() + 10
            while time.time() < deadline and not tr.stats["orphans_reaped"]:
                time.sleep(0.05)
            assert tr.stats["orphans_reaped"] >= 1
        finally:
            tr.close()

    def test_bounded_close_with_frozen_worker(self):
        tr = _mesh(3)
        tr.start()
        import os
        os.kill(tr.worker_pid(1), signal.SIGSTOP)
        t0 = time.perf_counter()
        tr.close()
        took = time.perf_counter() - t0
        assert took < tr.join_timeout_s + 5.0
        for w in range(3):
            assert tr._procs[w].poll() is not None     # all reaped
        tr.close()                                     # idempotent

    def test_lazy_until_first_round(self):
        tr = _mesh(3)
        assert not tr._procs and tr._listener is None
        tr.close()


# --------------------------------------------------------------------------
# cross-backend parity + the defended SIGKILL round (Session level)
# --------------------------------------------------------------------------

def _parity_spec(backend, encrypt=None, fused=None):
    return ClusterSpec.from_dict({
        "code": {"scheme": "spacdc", "n_workers": 5, "k_blocks": 2,
                 "fused": fused},
        "straggler": {"n_stragglers": 0, "delay_s": 0.02},
        "transport": {"backend": backend, "heartbeat_s": 0.1,
                      "liveness_timeout_s": 1.5},
        "crypto": {"encrypt": encrypt},
        "seed": 7,
    })


def _run_matmul(spec):
    rng = np.random.default_rng(0)
    a = rng.standard_normal((8, 6)).astype(np.float32)
    b = rng.standard_normal((6, 4)).astype(np.float32)
    with Session(spec) as s:
        out, stats = s.matmul(a, b, round_idx=0)
    return np.asarray(a @ b), out, stats


class TestCrossBackendParity:
    def test_plain_trace_bit_identical_virtual_threads_socket(self):
        # the virtual clock's loop path (fused=False) runs the same task
        # math as the real backends — one clean trace, three transports,
        # identical bits
        _, o_virtual, _ = _run_matmul(_parity_spec("virtual", fused=False))
        _, o_threads, _ = _run_matmul(_parity_spec("threads"))
        _, o_socket, _ = _run_matmul(_parity_spec("socket"))
        assert np.array_equal(o_virtual, o_threads)
        assert np.array_equal(o_threads, o_socket)

    def test_real_crypto_trace_bit_identical_and_sealed(self):
        _, o_virtual, _ = _run_matmul(
            _parity_spec("virtual", encrypt="real", fused=False))
        _, o_threads, _ = _run_matmul(_parity_spec("threads",
                                                   encrypt="real"))
        _, o_socket, st = _run_matmul(_parity_spec("socket",
                                                   encrypt="real"))
        assert np.array_equal(o_virtual, o_threads)
        assert np.array_equal(o_threads, o_socket)
        assert st.crypto_s > 0          # the sealed wire was measured

    def test_defended_sigkill_round_completes(self):
        # a live worker is SIGKILLed mid-round; the defended socket round
        # re-dispatches its slot and still decodes at reference accuracy
        spec = ClusterSpec.from_dict({
            "code": {"scheme": "spacdc", "n_workers": 6, "k_blocks": 2},
            "straggler": {"n_stragglers": 0, "delay_s": 0.02},
            "transport": {"backend": "socket", "heartbeat_s": 0.1,
                          "liveness_timeout_s": 1.5},
            "fault": {"crash_rate": 0.25, "handle": True, "os_level": True,
                      "seed": 139, "worker_timeout_s": 1.5,
                      "max_retries": 3},
            "seed": 7,
        })
        rng = np.random.default_rng(0)
        a = rng.standard_normal((8, 6)).astype(np.float32)
        b = rng.standard_normal((6, 4)).astype(np.float32)
        with Session(spec) as s:
            out, stats = s.matmul(a, b, round_idx=0)
            kills = s.engine.pool.transport.stats["kills"]
            health = s.engine.health.to_dict()
        ref = a @ b
        rel = float(np.linalg.norm(out - ref) / np.linalg.norm(ref))
        assert kills >= 1                      # a real PID died
        assert stats.retries >= 1              # ...and was re-dispatched
        assert not stats.degraded
        assert rel <= 1e-2
        crashed = [w for w in health["workers"] if w["n_crash"] > 0]
        assert crashed                         # the kill is in the record
        assert json.dumps(health)              # and it serializes

    def test_defended_sigkill_matches_simulated_threads(self):
        # same seeded fault plan, physical on the mesh vs simulated on
        # threads: the defended decode is bit-identical
        def run(backend):
            spec = ClusterSpec.from_dict({
                "code": {"scheme": "spacdc", "n_workers": 6,
                         "k_blocks": 2},
                "straggler": {"n_stragglers": 0, "delay_s": 0.02},
                "transport": {"backend": backend, "heartbeat_s": 0.1,
                              "liveness_timeout_s": 1.5},
                "fault": {"crash_rate": 0.25, "handle": True,
                          "os_level": backend == "socket", "seed": 139,
                          "worker_timeout_s": 1.5, "max_retries": 3},
                "seed": 7,
            })
            rng = np.random.default_rng(0)
            a = rng.standard_normal((8, 6)).astype(np.float32)
            b = rng.standard_normal((6, 4)).astype(np.float32)
            with Session(spec) as s:
                out, _ = s.matmul(a, b, round_idx=0)
            return out
        assert np.array_equal(run("socket"), run("threads"))
